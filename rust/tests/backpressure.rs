//! Backpressure acceptance: the async data plane must turn one slow
//! receiver into *bounded lag* — never a writer stall, never unbounded
//! inbox memory, and never a changed output byte.
//!
//! Three claims, matching the credit-based backpressure design:
//!
//! 1. a receiver drained 10× slower than the gossip cadence leaves
//!    writer throughput within 20% of the uniform run (senders enqueue
//!    and move on; parking is the receiver's problem);
//! 2. `inbox_depth_max` stays ≤ `inbox_capacity` — the memory bound the
//!    cap exists to provide;
//! 3. outputs under backpressure are byte-identical to an unconstrained
//!    run over the same pre-seeded input — parked and shed gossip is
//!    bounded staleness, and windowed-CRDT outputs are a function of
//!    the input alone.

use holon::clock::SimClock;
use holon::codec::Encode;
use holon::config::HolonConfig;
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::experiments::run_overload;
use holon::log::Topic;
use holon::nexmark::queries::Q7;
use holon::nexmark::NexmarkGen;

fn cfg(seed: u64) -> HolonConfig {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 3;
    cfg.partitions = 6;
    cfg.events_per_sec_per_partition = 500;
    cfg.wall_ms_per_sim_sec = 10.0;
    cfg.duration_ms = 4000;
    cfg.window_ms = 1000;
    cfg.gossip_interval_ms = 50;
    cfg.heartbeat_interval_ms = 150;
    cfg.seed = seed;
    cfg
}

/// Deduplicated inner payloads per partition (the determinism-suite
/// oracle view of a run's output).
fn dedup_payloads(output: &Topic, partitions: u32) -> Vec<Vec<Vec<u8>>> {
    (0..partitions)
        .map(|p| {
            let (recs, _) = output.read(p, 0, usize::MAX >> 1);
            let mut seen = 0u64;
            let mut outs = Vec::new();
            for rec in recs {
                let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
                if seq < seen {
                    continue;
                }
                seen = seq + 1;
                outs.push(inner.to_vec());
            }
            outs
        })
        .collect()
}

/// Pre-seed a byte-identical input log (live rate-based producers jitter
/// event timestamps, which would compare different inputs, not different
/// transports).
fn seed_input(input: &Topic, cfg: &HolonConfig) {
    for p in 0..cfg.partitions {
        let mut gen = NexmarkGen::new(cfg.seed, p);
        let n = cfg.events_per_sec_per_partition * cfg.duration_ms / 1000;
        let batch: Vec<(u64, Vec<u8>)> = (0..n)
            .map(|i| {
                let ts = i * 1000 / cfg.events_per_sec_per_partition;
                (ts, gen.next_event().to_bytes())
            })
            .collect();
        input.append_batch(p, batch);
    }
}

#[test]
fn slow_receiver_leaves_writers_within_20_percent_and_inbox_bounded() {
    let mut base = cfg(61);
    // tight enough that the gossip+heartbeat traffic arriving between
    // two 10×-slowed drains demonstrably overruns it
    base.inbox_capacity = 16;
    let uniform = run_overload(&base, false);
    let slow = run_overload(&base, true);

    assert!(uniform.consumed > 0, "uniform run consumed nothing");
    assert!(!slow.stalled, "slow-receiver run stalled outright");
    // (a) writer throughput independent of the stalled peer's depth:
    // within 20% of the uniform run (the acceptance bound)
    assert!(
        slow.consumed * 5 >= uniform.consumed * 4,
        "slow receiver dragged writers down: {} vs {} consumed",
        slow.consumed,
        uniform.consumed
    );
    // (b) inbox memory bounded by inbox_capacity
    let dp = &slow.data_plane;
    assert!(
        dp.inbox_depth_max > 0 && dp.inbox_depth_max <= 16,
        "inbox depth must be bounded by the cap: {dp:?}"
    );
    // the stalled peer's overflow actually parked — backpressure engaged
    // rather than the cap silently never binding
    assert!(
        dp.credits_stalled_rounds > 0,
        "a 10x-slowed receiver never triggered backpressure: {dp:?}"
    );
    assert!(
        dp.outbound_queue_depth_max > 0,
        "nothing ever queued outbound: {dp:?}"
    );
    // uniform run under the same cap also stays bounded
    assert!(uniform.data_plane.inbox_depth_max <= 16);
    // and the delivery audit holds in both runs
    assert_eq!(slow.data_plane.gaps, 0);
    assert_eq!(uniform.data_plane.gaps, 0);
}

#[test]
fn backpressure_does_not_change_a_single_output_byte() {
    // Unconstrained run: unbounded inboxes, no phantom receiver.
    let plain_cfg = cfg(67);
    let clock = SimClock::scaled(plain_cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(plain_cfg.clone(), Q7::new(1000), clock.clone());
    seed_input(&cluster.input, &plain_cfg);
    std::thread::sleep(clock.wall_for(plain_cfg.duration_ms + 3500));
    cluster.stop();
    let plain = dedup_payloads(&cluster.output, plain_cfg.partitions);

    // Backpressured run over the SAME input bytes: tight inbox cap plus
    // a phantom receiver that never drains at all (worst case — its
    // inbox pins at capacity, its parked queues shed continuously).
    let mut bp_cfg = cfg(67);
    bp_cfg.inbox_capacity = 8;
    let clock = SimClock::scaled(bp_cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(bp_cfg.clone(), Q7::new(1000), clock.clone());
    cluster.bus.register(bp_cfg.nodes + 1000); // phantom: inbox, no drain
    seed_input(&cluster.input, &bp_cfg);
    std::thread::sleep(clock.wall_for(bp_cfg.duration_ms + 3500));
    cluster.stop();
    let pressured = dedup_payloads(&cluster.output, bp_cfg.partitions);

    // the cap held even against a never-draining peer
    assert!(cluster.bus.inbox_depth_max() <= 8);

    // byte-identical completed prefix, partition by partition
    assert_eq!(plain.len(), pressured.len());
    for (p, (pa, pb)) in plain.iter().zip(pressured.iter()).enumerate() {
        let common = pa.len().min(pb.len());
        assert!(common >= 2, "partition {p}: only {common} common outputs");
        for i in 0..common {
            assert_eq!(pa[i], pb[i], "partition {p}, output {i} differs");
        }
    }
}
