//! Read-path acceptance: any converged replica answers queries
//! identically (byte for byte) within its declared staleness bound,
//! the signature-index pre-filter never produces a false negative, and
//! the changefeed delivers every gossip payload exactly once with
//! cursor resume across subscriber drops and node restarts.

use std::collections::BTreeSet;

use holon::clock::SimClock;
use holon::codec::Encode;
use holon::config::HolonConfig;
use holon::crdt::{GCounter, MapCrdt, PrefixAgg};
use holon::engine::HolonCluster;
use holon::log::Topic;
use holon::nexmark::queries::dataflow_q4_sharded;
use holon::nexmark::{NexmarkGen, CATEGORIES};
use holon::query::{fingerprint, QueryEngine, QueryError};
use holon::shard::ShardedMapCrdt;
use holon::sim::{run_plan_with, FaultPlan, SimSpec};
use holon::wcrdt::{WindowAssigner, WindowedCrdt};

type Q4State = WindowedCrdt<ShardedMapCrdt<u64, PrefixAgg>>;
type Q4Engine = QueryEngine<ShardedMapCrdt<u64, PrefixAgg>>;

/// Canonical byte encoding of one engine's answers over a window range:
/// per window, every category's point value, the full range scan, and
/// the top-3. Two replicas agree iff these bytes agree.
fn answers(q: &mut Q4Engine, lo: u64, hi: u64) -> Vec<u8> {
    let mut out = Vec::new();
    for wid in lo..=hi {
        for cat in 0..CATEGORIES {
            let r = q.point(wid, &cat, 0).expect("complete window at staleness 0");
            assert!(r.is_final, "window {wid} must be final at staleness 0");
            match r.value {
                Some(agg) => {
                    out.push(1);
                    out.extend(agg.to_bytes());
                }
                None => out.push(0),
            }
        }
        let range = q.range(wid, &0, &(CATEGORIES - 1), 0).unwrap();
        for (k, v) in &range.value {
            out.extend(k.to_bytes());
            out.extend(v.to_bytes());
        }
        let top = q.top_k(wid, 3, 0).unwrap();
        for (k, v) in &top.value {
            out.extend(k.to_bytes());
            out.extend(v.to_bytes());
        }
    }
    out
}

#[test]
fn any_replica_queries_converge_under_faults() {
    // Run the sharded Q4 pipeline through a seeded kill/restart/
    // partition/burst schedule, then query every surviving replica
    // directly — no coordination, no designated leader. For every
    // window complete on all of them, point/range/top-k answers must
    // be byte-identical (the §3.3 determinism claim, served as reads).
    let spec = SimSpec { seed: 91, ..SimSpec::default() };
    let plan = FaultPlan::generate(91, spec.nodes, spec.fault_window());
    let art = run_plan_with(&spec, &plan, None, dataflow_q4_sharded(spec.window_ms, 8));
    assert!(art.replicas.len() >= 2, "need >= 2 surviving replicas");

    let mut engines: Vec<(u32, Q4Engine)> = art
        .replicas
        .iter()
        .map(|(&n, bytes)| {
            (n, QueryEngine::new(Q4State::from_bytes(bytes).expect("decodable replica")))
        })
        .collect();

    // the windows final on every replica
    let lo = engines
        .iter()
        .map(|(_, q)| q.state().first_available())
        .max()
        .unwrap();
    let hi = engines
        .iter()
        .map(|(_, q)| q.state().completed_up_to().expect("completed windows"))
        .min()
        .unwrap();
    assert!(hi > lo, "need >= 2 comparable windows (got [{lo}, {hi}])");

    let reference = answers(&mut engines[0].1, lo, hi);
    assert!(!reference.is_empty());
    for (node, q) in engines.iter_mut().skip(1) {
        assert_eq!(
            answers(q, lo, hi),
            reference,
            "replica {node} disagrees with replica {} on final windows [{lo}, {hi}]",
            engines_first_node(&art)
        );
    }

    // Staleness gate per replica: the first incomplete window is
    // rejected at staleness 0 but readable as a non-final value under
    // a one-window bound (its lag is at most window_ms by definition).
    for (_, q) in engines.iter_mut() {
        let c = q.state().completed_up_to().unwrap();
        match q.point(c + 1, &0, 0) {
            Err(QueryError::TooStale { lag_ms, bound_ms: 0 }) => assert!(lag_ms > 0),
            other => panic!("incomplete window must be TooStale at 0, got {other:?}"),
        }
        let near = q.point(c + 1, &0, spec.window_ms).unwrap();
        assert!(!near.is_final);
        assert!(near.lag_ms > 0 && near.lag_ms <= spec.window_ms);
    }
}

fn engines_first_node(art: &holon::sim::RunArtifacts) -> u32 {
    *art.replicas.keys().next().unwrap()
}

struct XorShift64(u64);

impl XorShift64 {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
}

#[test]
fn index_prefilter_has_zero_false_negatives() {
    // Property: for every (window, key) ever written, a reader that
    // ingested the writer's state — through any interleaving of delta
    // and full-state payloads — must (a) pass the Bloom/shard
    // pre-filter and (b) find the key with a point lookup. The filter
    // may only prune truly-absent keys.
    for seed in [3u64, 41, 1999] {
        // flat MapCrdt state
        let mut rng = XorShift64(seed | 1);
        let assigner = WindowAssigner::tumbling(1000);
        let mut writer: WindowedCrdt<MapCrdt<u64, GCounter>> =
            WindowedCrdt::new(assigner, [0u32].iter().copied());
        let mut reader = QueryEngine::new(WindowedCrdt::<MapCrdt<u64, GCounter>>::new(
            assigner,
            [0u32].iter().copied(),
        ));
        let mut inserted: BTreeSet<(u64, u64)> = BTreeSet::new();
        for step in 0..400u64 {
            let wid = rng.next() % 6;
            let key = rng.next() % 512;
            let ts = wid * 1000 + rng.next() % 1000;
            writer.insert_with(0, ts, |m| m.entry(key).add(0, 1)).unwrap();
            inserted.insert((wid, key));
            if step % 7 == 0 {
                reader.ingest(&writer.take_delta());
            }
            if step % 97 == 0 {
                reader.ingest(&writer); // periodic full-state anti-entropy
            }
        }
        reader.ingest(&writer.take_delta());
        for &(wid, key) in &inserted {
            assert!(
                reader.index().may_contain(wid, fingerprint(&key)),
                "flat seed {seed}: filter lost window {wid} key {key}"
            );
            let r = reader.point(wid, &key, u64::MAX).unwrap();
            assert!(r.value.is_some(), "flat seed {seed}: window {wid} key {key} pruned");
        }

        // sharded state: deltas carry dirty shards only, and the reader
        // starts bottom (0 shards) so merges cross shard layouts
        let mut rng = XorShift64(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
        let mut writer: Q4State = WindowedCrdt::new(assigner, [0u32].iter().copied());
        let mut reader: Q4Engine =
            QueryEngine::new(WindowedCrdt::new(assigner, [0u32].iter().copied()));
        let mut inserted: BTreeSet<(u64, u64)> = BTreeSet::new();
        for step in 0..400u64 {
            let wid = rng.next() % 6;
            let key = rng.next() % 512;
            let ts = wid * 1000 + rng.next() % 1000;
            writer
                .insert_with(0, ts, |m| {
                    m.ensure_shards(8);
                    m.entry(key).observe(0, 1.0);
                })
                .unwrap();
            inserted.insert((wid, key));
            if step % 5 == 0 {
                reader.ingest(&writer.take_delta());
            }
            if step % 89 == 0 {
                reader.ingest(&writer);
            }
        }
        reader.ingest(&writer.take_delta());
        for &(wid, key) in &inserted {
            assert!(
                reader.index().may_contain(wid, fingerprint(&key)),
                "sharded seed {seed}: filter lost window {wid} key {key}"
            );
            let r = reader.point(wid, &key, u64::MAX).unwrap();
            assert!(
                r.value.is_some(),
                "sharded seed {seed}: window {wid} key {key} pruned"
            );
        }
    }
}

/// Pre-seed a byte-identical input log (same idiom as determinism.rs:
/// timestamps are a pure function of the index).
fn seed_input(input: &Topic, cfg: &HolonConfig) {
    for p in 0..cfg.partitions {
        let mut gen = NexmarkGen::new(cfg.seed, p);
        let n = cfg.events_per_sec_per_partition * cfg.duration_ms / 1000;
        let batch: Vec<(u64, Vec<u8>)> = (0..n)
            .map(|i| {
                let ts = i * 1000 / cfg.events_per_sec_per_partition;
                (ts, gen.next_event().to_bytes())
            })
            .collect();
        input.append_batch(p, batch);
    }
}

#[test]
fn changefeed_delivers_every_delta_exactly_once_with_resume() {
    // Subscribe to node 0's changefeed before the run, drop the
    // subscription mid-stream and resume from the saved cursor, and
    // kill/restart node 1 while subscribed to it. Every published
    // payload must arrive exactly once with strictly consecutive
    // cursors, the restarted node must keep publishing into the SAME
    // feed (cursors survive the restart), and an engine built purely
    // from the feed must answer byte-identically to node 0's final
    // replica.
    let mut cfg = HolonConfig::default();
    cfg.nodes = 4;
    cfg.partitions = 8;
    cfg.events_per_sec_per_partition = 1000;
    cfg.wall_ms_per_sim_sec = 50.0;
    cfg.duration_ms = 6000;
    cfg.window_ms = 1000;
    cfg.gossip_interval_ms = 50;
    cfg.gossip_delta = true;
    cfg.seed = 97;

    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg.clone(), dataflow_q4_sharded(1000, 8), clock.clone());
    seed_input(&cluster.input, &cfg);

    let h0 = cluster.read_handle(0).expect("node 0 read handle");
    let mut sub0 = h0.subscribe_at(0);
    let h1 = cluster.read_handle(1).expect("node 1 read handle");
    let mut sub1 = h1.subscribe_at(0);

    std::thread::sleep(clock.wall_for(2000));
    cluster.fail_node(1);
    let pre_kill_cursor = h1.latest_cursor();
    std::thread::sleep(clock.wall_for(1500));
    cluster.restart_node(1);
    std::thread::sleep(clock.wall_for(cfg.duration_ms - 3500 + 4000));
    cluster.stop();

    // node 0: poll a prefix, drop, resume from the saved cursor
    let mut items = sub0.poll(40).expect("within retention");
    let saved = sub0.cursor();
    assert_eq!(saved, items.len() as u64);
    drop(sub0);
    let mut resumed = h0.subscribe_at(saved);
    loop {
        let batch = resumed.poll(64).expect("within retention");
        if batch.is_empty() {
            break;
        }
        items.extend(batch);
    }
    assert!(items.len() > 10, "only {} feed items", items.len());
    for (i, item) in items.iter().enumerate() {
        assert_eq!(item.cursor, i as u64, "cursor gap or duplicate at {i}");
    }
    assert_eq!(h0.latest_cursor(), items.len() as u64);
    assert!(items.iter().any(|i| i.full), "full-sync rounds must be in the feed");
    assert!(items.iter().any(|i| !i.full), "delta rounds must be in the feed");

    // node 1: the restart must append to the same feed, not reset it
    assert!(
        h1.latest_cursor() > pre_kill_cursor,
        "restarted node stopped publishing (cursor stuck at {pre_kill_cursor})"
    );
    let restarted: Vec<_> = {
        let mut all = Vec::new();
        loop {
            let batch = sub1.poll(64).expect("within retention");
            if batch.is_empty() {
                break;
            }
            all.extend(batch);
        }
        all
    };
    for (i, item) in restarted.iter().enumerate() {
        assert_eq!(item.cursor, i as u64, "node 1 cursor break at {i} (restart reset?)");
    }

    // an engine fed only by the changefeed equals the final replica
    let mut feed_engine: Q4Engine =
        QueryEngine::new(WindowedCrdt::new(WindowAssigner::tumbling(1000), std::iter::empty()));
    for item in &items {
        assert!(feed_engine.apply_feed(item).expect("decodable payload"));
    }
    assert_eq!(feed_engine.cursor(), items.len() as u64);

    let replicas = cluster.final_replicas();
    let mut direct =
        QueryEngine::new(Q4State::from_bytes(&replicas[&0]).expect("decodable replica"));
    assert_eq!(
        feed_engine.state().global_watermark(),
        direct.state().global_watermark(),
        "feed-built engine watermark diverges from the replica"
    );
    let lo = direct
        .state()
        .first_available()
        .max(feed_engine.state().first_available());
    let hi = direct.state().completed_up_to().expect("completed windows");
    assert!(hi >= lo, "no comparable window ([{lo}, {hi}])");
    assert_eq!(
        answers(&mut feed_engine, lo, hi),
        answers(&mut direct, lo, hi),
        "feed-built engine answers diverge from the replica's"
    );
}
