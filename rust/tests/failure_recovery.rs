//! Failure injection on a running cluster (paper §5.2 scenarios):
//! concurrent node failures, subsequent failures, crash (no restart),
//! and network partitions. The paper's claims under test:
//!
//! * the system keeps making progress (work stealing reassigns the
//!   failed nodes' partitions);
//! * outputs stay correct and deterministic across partitions despite
//!   replays (exactly-once effects, idempotent outputs);
//! * after a crash the system reconfigures and continues (no stall).

use holon::clock::SimClock;
use holon::codec::Decode;
use holon::config::HolonConfig;
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::nexmark::producer;
use holon::nexmark::queries::{Q7Out, Q7};

fn cfg() -> HolonConfig {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 5;
    cfg.partitions = 10;
    cfg.events_per_sec_per_partition = 1000;
    cfg.wall_ms_per_sim_sec = 50.0;
    cfg.duration_ms = 10_000;
    cfg.window_ms = 1000;
    cfg.gossip_interval_ms = 50;
    cfg.checkpoint_interval_ms = 500;
    cfg.heartbeat_interval_ms = 200;
    cfg.failure_timeout_ms = 1000;
    cfg
}

fn collect_q7(cluster: &HolonCluster<Q7>) -> Vec<Vec<Q7Out>> {
    let mut per_part = Vec::new();
    for p in 0..cluster.cfg.partitions {
        let (recs, _) = cluster.output.read(p, 0, usize::MAX >> 1);
        let mut seen = 0u64;
        let mut outs = Vec::new();
        for rec in recs {
            let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
            if seq < seen {
                continue;
            }
            seen = seq + 1;
            outs.push(Q7Out::from_bytes(&inner).unwrap());
        }
        per_part.push(outs);
    }
    per_part
}

fn assert_consistent(outs: &[Vec<Q7Out>], min_windows_expected: usize) {
    let min_windows = outs.iter().map(|o| o.len()).min().unwrap();
    assert!(
        min_windows >= min_windows_expected,
        "windows per partition: {:?}",
        outs.iter().map(|o| o.len()).collect::<Vec<_>>()
    );
    for part in outs {
        for (i, o) in part.iter().enumerate() {
            assert_eq!(o.window, i as u64, "gap/out-of-order emission");
        }
    }
    for w in 0..min_windows {
        for part in &outs[1..] {
            assert_eq!(part[w], outs[0][w], "divergent window {w} after recovery");
        }
    }
}

#[test]
fn concurrent_failures_recover_and_stay_consistent() {
    let cfg = cfg();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q7::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );

    // let it warm up for 3 sim-seconds
    std::thread::sleep(clock.wall_for(3000));
    // fail two nodes at once
    cluster.fail_node(1);
    cluster.fail_node(2);
    assert_eq!(cluster.running_nodes(), vec![0, 3, 4]);
    // restart them 2 sim-seconds later (scaled-down version of the
    // paper's 10 s restart; intervals are scaled consistently)
    std::thread::sleep(clock.wall_for(2000));
    cluster.restart_node(1);
    cluster.restart_node(2);

    std::thread::sleep(clock.wall_for(cfg.duration_ms - 5000 + 4000));
    prod.stop();
    cluster.stop();

    let outs = collect_q7(&cluster);
    assert_consistent(&outs, 6);
    // work stealing must actually have happened
    assert!(cluster.metrics.steals.load(std::sync::atomic::Ordering::Acquire) > 10);
}

#[test]
fn crash_without_restart_keeps_progress() {
    let cfg = cfg();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q7::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(3000));
    let before = cluster.metrics.outputs.load(std::sync::atomic::Ordering::Acquire);
    cluster.fail_node(0);
    cluster.fail_node(4);
    // never restarted — survivors must absorb the partitions
    std::thread::sleep(clock.wall_for(cfg.duration_ms - 3000 + 4000));
    prod.stop();
    cluster.stop();

    let after = cluster.metrics.outputs.load(std::sync::atomic::Ordering::Acquire);
    assert!(after > before + 10, "no progress after crash: {before} -> {after}");
    let outs = collect_q7(&cluster);
    assert_consistent(&outs, 6);
}

#[test]
fn subsequent_failures_recover() {
    let cfg = cfg();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q7::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(3000));
    cluster.fail_node(1);
    std::thread::sleep(clock.wall_for(1000)); // second failure 1 s later
    cluster.fail_node(3);
    std::thread::sleep(clock.wall_for(2000));
    cluster.restart_node(1);
    cluster.restart_node(3);
    std::thread::sleep(clock.wall_for(cfg.duration_ms - 6000 + 4000));
    prod.stop();
    cluster.stop();
    assert_consistent(&collect_q7(&cluster), 6);
}

#[test]
fn network_partition_updates_remain_available() {
    // The paper's CAP trade-off (§2.5): updating state stays available
    // under a network partition; reads of *completed* windows wait (the
    // global watermark cannot advance across the cut), and everything
    // converges after healing.
    let cfg = cfg();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q7::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(2000));
    // cut the cluster in two for 3 sim-seconds
    cluster.bus.set_partition(&[&[0, 1], &[2, 3, 4]]);
    std::thread::sleep(clock.wall_for(3000));
    // processing continued during the cut (updates available)
    let during = cluster.metrics.processed.counts().iter().sum::<u64>();
    assert!(during > 0);
    cluster.bus.heal_partition();
    std::thread::sleep(clock.wall_for(cfg.duration_ms - 5000 + 4000));
    prod.stop();
    cluster.stop();

    // after healing, all partitions converge and agree
    assert_consistent(&collect_q7(&cluster), 6);
}
