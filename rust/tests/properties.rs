//! Randomized property tests (proptest_lite): CRDT lattice laws over
//! generated states, WCRDT convergence/determinism invariants, codec
//! round-trips, and coordinator assignment invariants.

// lint:allow-file(discarded-merge): property suites merge for effect across random schedules; outcomes are checked by the dedicated merge_outcome properties
use std::collections::BTreeMap;

use holon::codec::{Decode, Encode, Writer};
use holon::crdt::{
    BoundedTopK, Crdt, GCounter, GSet, LwwRegister, MapCrdt, MaxRegister, MergeOutcome,
    MinRegister, ORSet, PNCounter, PrefixAgg, TwoPSet,
};
use holon::engine::membership::{assignment, target_owner};
use holon::proptest_lite::forall;
use holon::shard::ShardedMapCrdt;
use holon::util::XorShift64;
use holon::wcrdt::{WindowAssigner, WindowId, WindowRing, WindowedCrdt};

// ---- generators -------------------------------------------------------

fn gen_gcounter(rng: &mut XorShift64, size: usize) -> GCounter {
    let mut g = GCounter::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        g.add(rng.next_below(8), rng.next_below(100));
    }
    g
}

fn gen_pncounter(rng: &mut XorShift64, size: usize) -> PNCounter {
    let mut g = PNCounter::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        if rng.chance(0.5) {
            g.add(rng.next_below(8), rng.next_below(100));
        } else {
            g.sub(rng.next_below(8), rng.next_below(100));
        }
    }
    g
}

fn gen_topk(rng: &mut XorShift64, size: usize) -> BoundedTopK {
    let mut t = BoundedTopK::new(4);
    for _ in 0..rng.next_below(size as u64 + 1) {
        t.offer(
            rng.next_f64() * 1000.0,
            rng.next_below(1000),
            rng.next_below(8),
        );
    }
    t
}

fn gen_orset(rng: &mut XorShift64, size: usize) -> ORSet<u64> {
    let mut s = ORSet::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        let v = rng.next_below(16);
        if rng.chance(0.7) {
            s.insert(rng.next_below(4), v);
        } else {
            s.remove(&v);
        }
    }
    s
}

fn gen_map(rng: &mut XorShift64, size: usize) -> MapCrdt<u64, GCounter> {
    let mut m: MapCrdt<u64, GCounter> = MapCrdt::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        m.entry(rng.next_below(6)).add(rng.next_below(8), rng.next_below(50));
    }
    m
}

fn gen_sharded_map(rng: &mut XorShift64, size: usize) -> ShardedMapCrdt<u64, GCounter> {
    let mut m: ShardedMapCrdt<u64, GCounter> = ShardedMapCrdt::with_shards(4);
    for _ in 0..rng.next_below(size as u64 + 1) {
        m.entry(rng.next_below(24)).add(rng.next_below(8), rng.next_below(50));
    }
    m
}

fn gen_lww(rng: &mut XorShift64, size: usize) -> LwwRegister<u64> {
    // Discipline: a (ts, contributor) pair always carries the same value
    // — execution guarantees this (a contributor's writes are
    // deterministic), and without it ties would not commute.
    let mut r = LwwRegister::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        let ts = rng.next_below(100);
        let c = rng.next_below(8);
        r.set(ts, c, ts * 1000 + c);
    }
    r
}

fn gen_maxreg(rng: &mut XorShift64, size: usize) -> MaxRegister<u64> {
    let mut r = MaxRegister::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        r.put(rng.next_below(10_000));
    }
    r
}

fn gen_minreg(rng: &mut XorShift64, size: usize) -> MinRegister<u64> {
    let mut r = MinRegister::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        r.put(rng.next_below(10_000));
    }
    r
}

fn gen_gset(rng: &mut XorShift64, size: usize) -> GSet<u64> {
    let mut s = GSet::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        s.insert(rng.next_below(32));
    }
    s
}

fn gen_2pset(rng: &mut XorShift64, size: usize) -> TwoPSet<u64> {
    let mut s = TwoPSet::new();
    for _ in 0..rng.next_below(size as u64 + 1) {
        let v = rng.next_below(24);
        if rng.chance(0.7) {
            s.insert(v);
        } else {
            s.remove(v);
        }
    }
    s
}

// ---- lattice laws over random states ----------------------------------

fn check_laws<C: Crdt + PartialEq + std::fmt::Debug>(a: &C, b: &C, c: &C) -> Result<(), String> {
    let ab = a.clone().merged(b);
    let ba = b.clone().merged(a);
    if ab != ba {
        return Err(format!("commutativity: {ab:?} != {ba:?}"));
    }
    let ab_c = a.clone().merged(b).merged(c);
    let a_bc = a.clone().merged(&b.clone().merged(c));
    if ab_c != a_bc {
        return Err("associativity".to_string());
    }
    let aa = a.clone().merged(a);
    if &aa != a {
        return Err("idempotence".to_string());
    }
    let bottom = C::default().merged(a);
    if &bottom != a {
        return Err("identity".to_string());
    }
    Ok(())
}

macro_rules! lattice_law_test {
    ($name:ident, $gen:ident) => {
        #[test]
        fn $name() {
            forall(
                stringify!($name),
                150,
                48,
                &|rng: &mut XorShift64, size: usize| {
                    ($gen(rng, size), $gen(rng, size), $gen(rng, size))
                },
                |(a, b, c)| check_laws(a, b, c),
            );
        }
    };
}

lattice_law_test!(gcounter_lattice_laws, gen_gcounter);
lattice_law_test!(pncounter_lattice_laws, gen_pncounter);
lattice_law_test!(topk_lattice_laws, gen_topk);
lattice_law_test!(orset_lattice_laws, gen_orset);
lattice_law_test!(mapcrdt_lattice_laws, gen_map);
lattice_law_test!(sharded_map_lattice_laws, gen_sharded_map);
lattice_law_test!(lww_register_lattice_laws, gen_lww);
lattice_law_test!(max_register_lattice_laws, gen_maxreg);
lattice_law_test!(min_register_lattice_laws, gen_minreg);
lattice_law_test!(gset_lattice_laws, gen_gset);
lattice_law_test!(twopset_lattice_laws, gen_2pset);

#[test]
fn prefix_agg_lattice_laws_under_prefix_discipline() {
    // PrefixAgg's join is only a lattice over *prefix-disciplined*
    // replicas (two states of the same contributor must be prefixes of
    // one common op sequence — which execution guarantees); a,b,c are
    // therefore three random cuts of shared per-contributor sequences.
    forall(
        "prefix agg lattice laws",
        150,
        32,
        &|rng: &mut XorShift64, size: usize| {
            let contributors = 1 + rng.next_below(4);
            let seqs: Vec<Vec<f64>> = (0..contributors)
                .map(|_| {
                    (0..rng.next_below(size as u64 + 1))
                        .map(|_| rng.next_below(10_000) as f64)
                        .collect()
                })
                .collect();
            let cut = |rng: &mut XorShift64| -> PrefixAgg {
                let mut a = PrefixAgg::new();
                for (c, seq) in seqs.iter().enumerate() {
                    let n = rng.next_below(seq.len() as u64 + 1) as usize;
                    for &v in &seq[..n] {
                        a.observe(c as u64, v);
                    }
                }
                a
            };
            let a = cut(rng);
            let b = cut(rng);
            let c = cut(rng);
            (a, b, c)
        },
        |(a, b, c)| check_laws(a, b, c),
    );
}

// ---- change-reporting merges (Crdt trait v3) ---------------------------
//
// The contract the delta-amplification fix rests on: `merge` returns
// `Changed` iff the target state actually differs afterwards (per
// `PartialEq`), and an immediate re-merge of the same source is always
// `Unchanged`. Checked over randomized state pairs for every CRDT,
// including the sharded and windowed compositions.

fn check_merge_outcome<C: Crdt + PartialEq + std::fmt::Debug>(a: &C, b: &C) -> Result<(), String> {
    let mut t = a.clone();
    let outcome = t.merge(b);
    if outcome.is_changed() != (&t != a) {
        return Err(format!(
            "outcome {outcome:?} but target {} (target {a:?}, source {b:?})",
            if &t != a { "changed" } else { "did not change" }
        ));
    }
    let settled = t.clone();
    if t.merge(b) != MergeOutcome::Unchanged {
        return Err("re-merge of the same source reported Changed".to_string());
    }
    if t != settled {
        return Err("re-merge of the same source mutated the target".to_string());
    }
    Ok(())
}

macro_rules! merge_outcome_test {
    ($name:ident, $gen:ident) => {
        #[test]
        fn $name() {
            forall(
                stringify!($name),
                150,
                48,
                &|rng: &mut XorShift64, size: usize| ($gen(rng, size), $gen(rng, size)),
                |(a, b)| check_merge_outcome(a, b),
            );
        }
    };
}

merge_outcome_test!(gcounter_merge_outcome, gen_gcounter);
merge_outcome_test!(pncounter_merge_outcome, gen_pncounter);
merge_outcome_test!(topk_merge_outcome, gen_topk);
merge_outcome_test!(orset_merge_outcome, gen_orset);
merge_outcome_test!(mapcrdt_merge_outcome, gen_map);
merge_outcome_test!(sharded_map_merge_outcome, gen_sharded_map);
merge_outcome_test!(lww_register_merge_outcome, gen_lww);
merge_outcome_test!(max_register_merge_outcome, gen_maxreg);
merge_outcome_test!(min_register_merge_outcome, gen_minreg);
merge_outcome_test!(gset_merge_outcome, gen_gset);
merge_outcome_test!(twopset_merge_outcome, gen_2pset);

#[test]
fn prefix_agg_merge_outcome_under_prefix_discipline() {
    // PrefixAgg's contract only holds over prefix-disciplined replicas
    // (same-contributor states are prefixes of one shared sequence —
    // which execution guarantees): generate two random cuts of shared
    // per-contributor sequences, like the laws test does.
    forall(
        "prefix agg merge outcome",
        120,
        32,
        &|rng: &mut XorShift64, size: usize| {
            let contributors = 1 + rng.next_below(4);
            let seqs: Vec<Vec<f64>> = (0..contributors)
                .map(|_| {
                    (0..rng.next_below(size as u64 + 1))
                        .map(|_| rng.next_below(10_000) as f64)
                        .collect()
                })
                .collect();
            let cut = |rng: &mut XorShift64| -> PrefixAgg {
                let mut a = PrefixAgg::new();
                for (c, seq) in seqs.iter().enumerate() {
                    let n = rng.next_below(seq.len() as u64 + 1) as usize;
                    for &v in &seq[..n] {
                        a.observe(c as u64, v);
                    }
                }
                a
            };
            let a = cut(rng);
            let b = cut(rng);
            (a, b)
        },
        |(a, b)| check_merge_outcome(a, b),
    );
}

#[test]
fn sharded_map_cross_layout_merge_outcome() {
    // the rehash path must honor the same contract as the fast path
    forall(
        "cross-layout merge outcome",
        100,
        32,
        &|rng: &mut XorShift64, size: usize| {
            let ops: Vec<(u64, u64, u64)> = (0..rng.next_below(size as u64 + 1))
                .map(|_| (rng.next_below(24), rng.next_below(8), rng.next_below(50)))
                .collect();
            let cut = rng.next_below(ops.len() as u64 + 1) as usize;
            (ops, cut)
        },
        |(ops, cut)| {
            let build = |shards: u32, ops: &[(u64, u64, u64)]| {
                let mut m: ShardedMapCrdt<u64, GCounter> = ShardedMapCrdt::with_shards(shards);
                for &(k, c, amount) in ops {
                    m.entry(k).add(c, amount);
                }
                m
            };
            let a = build(4, &ops[..*cut]);
            let b = build(16, &ops[*cut..]);
            check_merge_outcome(&a, &b)
        },
    );
}

#[test]
fn wcrdt_merge_outcome_matches_state_change() {
    forall(
        "wcrdt merge outcome",
        80,
        32,
        &|rng: &mut XorShift64, size: usize| {
            let build = |rng: &mut XorShift64| {
                let mut w: WindowedCrdt<GCounter> =
                    WindowedCrdt::new(WindowAssigner::tumbling(500), [0, 1]);
                let mut ts = 0;
                for _ in 0..rng.next_below(size as u64 + 1) {
                    ts += rng.next_below(300);
                    let p = rng.next_below(2) as u32;
                    let _ = w.insert_with(p, ts, |c| c.add(p as u64, 1 + rng.next_below(5)));
                }
                if rng.chance(0.7) {
                    w.increment_watermark(rng.next_below(2) as u32, ts);
                }
                w
            };
            (build(rng), build(rng))
        },
        |(a, b)| {
            let mut t = a.clone();
            let report = t.merge(b);
            if report.outcome().is_changed() != (&t != a) {
                return Err(format!("report {report:?} disagrees with state change"));
            }
            // the changed-window set is exact: re-merging reports nothing
            let settled = t.clone();
            let again = t.merge(b);
            if again != holon::wcrdt::MergeReport::default() || t != settled {
                return Err(format!("re-merge not a no-op: {again:?}"));
            }
            Ok(())
        },
    );
}

// ---- merge-vs-sequential-apply equivalence ------------------------------
//
// The operational core of the paper's idempotent-replay argument: ops
// split across replicas (each contributor's ops staying on one replica,
// as partition ownership guarantees) and then merged must equal the
// same ops applied sequentially to a single replica.

fn split_vs_sequential<C, Op>(
    ops: &[(u64, Op)],
    apply: impl Fn(&mut C, u64, &Op),
) -> Result<(), String>
where
    C: Crdt + PartialEq + std::fmt::Debug,
{
    let mut seq = C::default();
    let mut even = C::default();
    let mut odd = C::default();
    for (contributor, op) in ops {
        apply(&mut seq, *contributor, op);
        if contributor % 2 == 0 {
            apply(&mut even, *contributor, op);
        } else {
            apply(&mut odd, *contributor, op);
        }
    }
    let ab = even.clone().merged(&odd);
    let ba = odd.merged(&even);
    if ab != ba {
        return Err(format!("merge not commutative: {ab:?} != {ba:?}"));
    }
    if ab != seq {
        return Err(format!(
            "split+merge != sequential apply: {ab:?} != {seq:?}"
        ));
    }
    Ok(())
}

macro_rules! split_equivalence_test {
    ($name:ident, $gen_ops:expr, $apply:expr) => {
        #[test]
        fn $name() {
            forall(
                stringify!($name),
                120,
                48,
                &|rng: &mut XorShift64, size: usize| {
                    let n = rng.next_below(size as u64 + 1);
                    (0..n).map(|_| $gen_ops(rng)).collect::<Vec<_>>()
                },
                |ops| split_vs_sequential(ops, $apply),
            );
        }
    };
}

split_equivalence_test!(
    gcounter_split_equivalence,
    |rng: &mut XorShift64| (rng.next_below(6), rng.next_below(100)),
    |c: &mut GCounter, contributor, n: &u64| c.add(contributor, *n)
);

split_equivalence_test!(
    pncounter_split_equivalence,
    |rng: &mut XorShift64| {
        (
            rng.next_below(6),
            (rng.next_below(100), rng.chance(0.5)),
        )
    },
    |c: &mut PNCounter, contributor, op: &(u64, bool)| {
        if op.1 {
            c.add(contributor, op.0)
        } else {
            c.sub(contributor, op.0)
        }
    }
);

split_equivalence_test!(
    prefix_agg_split_equivalence,
    |rng: &mut XorShift64| (rng.next_below(6), rng.next_below(10_000) as f64),
    |a: &mut PrefixAgg, contributor, v: &f64| a.observe(contributor, *v)
);

split_equivalence_test!(
    topk_split_equivalence,
    |rng: &mut XorShift64| {
        (
            rng.next_below(6),
            (rng.next_f64() * 1000.0, rng.next_below(1000)),
        )
    },
    |t: &mut BoundedTopK, contributor, op: &(f64, u64)| {
        t.set_k(4);
        t.offer(op.0, op.1, contributor)
    }
);

split_equivalence_test!(
    map_split_equivalence,
    |rng: &mut XorShift64| {
        (
            rng.next_below(6),
            (rng.next_below(5), rng.next_below(50)),
        )
    },
    |m: &mut MapCrdt<u64, GCounter>, contributor, op: &(u64, u64)| {
        m.entry(op.0).add(contributor, op.1)
    }
);

split_equivalence_test!(
    sharded_map_split_equivalence,
    |rng: &mut XorShift64| {
        (
            rng.next_below(6),
            (rng.next_below(24), rng.next_below(50)),
        )
    },
    |m: &mut ShardedMapCrdt<u64, GCounter>, contributor, op: &(u64, u64)| {
        m.ensure_shards(4);
        m.entry(op.0).add(contributor, op.1)
    }
);

split_equivalence_test!(
    gset_split_equivalence,
    |rng: &mut XorShift64| {
        let c = rng.next_below(6);
        (c, c * 1000 + rng.next_below(20))
    },
    |s: &mut GSet<u64>, _contributor, v: &u64| s.insert(*v)
);

split_equivalence_test!(
    twopset_split_equivalence,
    |rng: &mut XorShift64| {
        let c = rng.next_below(6);
        (c, (c * 1000 + rng.next_below(20), rng.chance(0.7)))
    },
    |s: &mut TwoPSet<u64>, _contributor, op: &(u64, bool)| {
        if op.1 {
            s.insert(op.0)
        } else {
            s.remove(op.0)
        }
    }
);

split_equivalence_test!(
    orset_split_equivalence,
    // values are namespaced per contributor so a remove only ever
    // observes dots its own replica added — the case where OR-set
    // split/merge and sequential application coincide
    |rng: &mut XorShift64| {
        let c = rng.next_below(6);
        (c, (c * 1000 + rng.next_below(12), rng.chance(0.7)))
    },
    |s: &mut ORSet<u64>, contributor, op: &(u64, bool)| {
        if op.1 {
            s.insert(contributor, op.0)
        } else {
            s.remove(&op.0)
        }
    }
);

split_equivalence_test!(
    lww_register_split_equivalence,
    |rng: &mut XorShift64| {
        let c = rng.next_below(6);
        (c, (rng.next_below(100), rng.next_below(1000)))
    },
    |r: &mut LwwRegister<u64>, contributor, op: &(u64, u64)| r.set(op.0, contributor, op.1)
);

split_equivalence_test!(
    max_register_split_equivalence,
    |rng: &mut XorShift64| (rng.next_below(6), rng.next_below(10_000)),
    |r: &mut MaxRegister<u64>, _contributor, v: &u64| r.put(*v)
);

split_equivalence_test!(
    min_register_split_equivalence,
    |rng: &mut XorShift64| (rng.next_below(6), rng.next_below(10_000)),
    |r: &mut MinRegister<u64>, _contributor, v: &u64| r.put(*v)
);

// ---- codec round-trips over random states ------------------------------

macro_rules! codec_roundtrip_test {
    ($name:ident, $gen:ident, $ty:ty) => {
        #[test]
        fn $name() {
            forall(
                stringify!($name),
                100,
                48,
                &|rng: &mut XorShift64, size: usize| $gen(rng, size),
                |v: &$ty| {
                    let b = v.to_bytes();
                    match <$ty>::from_bytes(&b) {
                        Ok(back) if &back == v => Ok(()),
                        Ok(back) => Err(format!("roundtrip mismatch: {back:?}")),
                        Err(e) => Err(format!("decode failed: {e}")),
                    }
                },
            );
        }
    };
}

codec_roundtrip_test!(gcounter_codec_roundtrip, gen_gcounter, GCounter);
codec_roundtrip_test!(topk_codec_roundtrip, gen_topk, BoundedTopK);
codec_roundtrip_test!(orset_codec_roundtrip, gen_orset, ORSet<u64>);
codec_roundtrip_test!(map_codec_roundtrip, gen_map, MapCrdt<u64, GCounter>);
codec_roundtrip_test!(
    sharded_map_codec_roundtrip,
    gen_sharded_map,
    ShardedMapCrdt<u64, GCounter>
);

// ---- sharded keyed state: layout independence --------------------------
//
// The shard layer must be *transparent*: the same ops through any shard
// count (including the flat MapCrdt) read back as the same logical map,
// merges across different layouts converge, and per-shard deltas join
// like full states. This is the algebra behind the engine-level
// determinism claim (sharded vs unsharded byte-identical outputs).

#[test]
fn sharded_map_is_layout_independent() {
    forall(
        "sharded layout independence",
        100,
        48,
        &|rng: &mut XorShift64, size: usize| {
            let n = rng.next_below(size as u64 + 1);
            (0..n)
                .map(|_| (rng.next_below(24), rng.next_below(8), rng.next_below(50)))
                .collect::<Vec<_>>()
        },
        |ops: &Vec<(u64, u64, u64)>| {
            let mut flat: MapCrdt<u64, GCounter> = MapCrdt::new();
            for &(k, c, a) in ops {
                flat.entry(k).add(c, a);
            }
            let flat_view: Vec<(u64, u64)> = flat.iter().map(|(&k, c)| (k, c.value())).collect();
            let mut replicas = Vec::new();
            for shards in [1u32, 2, 4, 16] {
                let mut m: ShardedMapCrdt<u64, GCounter> = ShardedMapCrdt::with_shards(shards);
                for &(k, c, a) in ops {
                    m.entry(k).add(c, a);
                }
                let view: Vec<(u64, u64)> = m.iter().map(|(&k, c)| (k, c.value())).collect();
                if view != flat_view {
                    return Err(format!("{shards} shards read differently: {view:?}"));
                }
                replicas.push(m);
            }
            // cross-layout merges still converge to the same logical map
            let mut merged = replicas[0].clone();
            let _ = merged.merge(&replicas[2]);
            if merged != replicas[3] {
                return Err("cross-layout merge diverged".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn sharded_map_delta_join_equals_full_join() {
    forall(
        "sharded delta join",
        100,
        32,
        &|rng: &mut XorShift64, size: usize| {
            let n = 1 + rng.next_below(size as u64 + 1);
            let ops: Vec<(u64, u64, u64)> = (0..n)
                .map(|_| (rng.next_below(24), rng.next_below(8), rng.next_below(50)))
                .collect();
            let cut = rng.next_below(n + 1) as usize;
            (ops, cut)
        },
        |(ops, cut)| {
            // replica A applies everything; replica B receives a full
            // state at `cut` and only per-shard deltas afterwards
            let mut a: ShardedMapCrdt<u64, GCounter> = ShardedMapCrdt::with_shards(8);
            let mut b: ShardedMapCrdt<u64, GCounter> = ShardedMapCrdt::with_shards(8);
            for &(k, c, amount) in &ops[..*cut] {
                a.entry(k).add(c, amount);
            }
            let _ = b.merge(&Crdt::take_delta(&mut a)); // full so far (all dirty)
            for &(k, c, amount) in &ops[*cut..] {
                a.entry(k).add(c, amount);
            }
            let delta = Crdt::take_delta(&mut a);
            let _ = b.merge(&delta);
            if b != a {
                return Err(format!("delta join diverged: {b:?} != {a:?}"));
            }
            Ok(())
        },
    );
}

// ---- WCRDT convergence: any merge order, same completed values ---------

#[test]
fn wcrdt_replicas_converge_in_any_merge_order() {
    forall(
        "wcrdt convergence",
        60,
        32,
        &|rng: &mut XorShift64, size: usize| {
            // per-partition update scripts: (partition, ts, amount)
            let parts = 2 + rng.next_below(4) as u32;
            let mut updates = Vec::new();
            for p in 0..parts {
                let n = rng.next_below(size as u64 + 1);
                let mut ts = 0;
                for _ in 0..n {
                    ts += rng.next_below(400);
                    updates.push((p, ts, 1 + rng.next_below(5)));
                }
            }
            (parts, updates, rng.next_u64())
        },
        |(parts, updates, shuffle_seed)| {
            let mk = || -> WindowedCrdt<GCounter> {
                WindowedCrdt::new(WindowAssigner::tumbling(1000), 0..*parts)
            };
            // one "source" replica per partition applies its own updates
            let mut sources: Vec<WindowedCrdt<GCounter>> = (0..*parts).map(|_| mk()).collect();
            let mut max_ts = vec![0u64; *parts as usize];
            for &(p, ts, n) in updates {
                sources[p as usize]
                    .insert_with(p, ts, |c| c.add(p as u64, n))
                    .map_err(|e| e.to_string())?;
                max_ts[p as usize] = max_ts[p as usize].max(ts);
            }
            for p in 0..*parts {
                sources[p as usize].increment_watermark(p, max_ts[p as usize] + 1000);
            }
            // replica A merges in order; replica B in a shuffled order
            let mut a = mk();
            for s in &sources {
                let _ = a.merge(s);
            }
            let mut b = mk();
            let mut order: Vec<usize> = (0..sources.len()).collect();
            let mut rng = XorShift64::new(*shuffle_seed);
            for i in (1..order.len()).rev() {
                order.swap(i, rng.next_below(i as u64 + 1) as usize);
            }
            for &i in &order {
                let _ = b.merge(&sources[i]);
            }
            if a != b {
                return Err("merge order changed the state".to_string());
            }
            // every completed window reads identically
            let gw = a.global_watermark();
            let mut w = 0;
            while (w + 1) * 1000 <= gw {
                if a.window_value(w) != b.window_value(w) {
                    return Err(format!("window {w} differs"));
                }
                w += 1;
            }
            Ok(())
        },
    );
}

#[test]
fn wcrdt_projection_roundtrip_preserves_contribution() {
    forall(
        "wcrdt projection",
        80,
        32,
        &|rng: &mut XorShift64, size: usize| {
            let mut w: WindowedCrdt<GCounter> =
                WindowedCrdt::new(WindowAssigner::tumbling(500), [0, 1, 2]);
            let mut ts = 0;
            for _ in 0..rng.next_below(size as u64 + 1) {
                ts += rng.next_below(300);
                let p = rng.next_below(3) as u32;
                let _ = w.insert_with(p, ts, |c| c.add(p as u64, 1));
            }
            w.increment_watermark(0, ts);
            w
        },
        |w| {
            use holon::api::SharedState;
            for p in 0..3u32 {
                let slice = SharedState::project(w, p);
                let mut joined = w.clone();
                let _ = joined.merge(&slice);
                if &joined != w {
                    return Err(format!("projection of {p} added information"));
                }
            }
            Ok(())
        },
    );
}

// ---- membership / assignment invariants --------------------------------

#[test]
fn assignment_is_total_and_stable_under_failures() {
    forall(
        "rendezvous assignment",
        100,
        16,
        &|rng: &mut XorShift64, size: usize| {
            let n = 2 + rng.next_below(size as u64 + 2) as u32;
            let kill = rng.next_below(n as u64) as u32;
            let partitions = 1 + rng.next_below(200) as u32;
            (n, kill, partitions)
        },
        |&(n, kill, partitions)| {
            let all: Vec<u32> = (0..n).collect();
            let survivors: Vec<u32> = (0..n).filter(|&x| x != kill).collect();
            let before = assignment(partitions, &all);
            let after = assignment(partitions, &survivors);
            for p in 0..partitions {
                if !survivors.contains(&after[&p]) {
                    return Err(format!("partition {p} assigned to dead node"));
                }
                if before[&p] != kill && before[&p] != after[&p] {
                    return Err(format!("partition {p} moved needlessly"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn target_owner_is_consistent_across_views() {
    // Two nodes with the same alive view must pick the same owner.
    forall(
        "owner consistency",
        100,
        12,
        &|rng: &mut XorShift64, size: usize| {
            let n = 1 + rng.next_below(size as u64 + 1) as u32;
            let p = rng.next_below(1000) as u32;
            (n, p)
        },
        |&(n, p)| {
            let alive: Vec<u32> = (0..n).collect();
            let a = target_owner(p, &alive);
            let b = target_owner(p, &alive);
            if a == b {
                Ok(())
            } else {
                Err("nondeterministic owner".to_string())
            }
        },
    );
}

// ---- PrefixAgg prefix discipline ----------------------------------------

#[test]
fn prefix_agg_replay_join_is_lossless() {
    // A checkpoint at any prefix, replayed forward, must join with the
    // full state to exactly the full state (the recovery identity).
    forall(
        "prefix replay",
        100,
        64,
        &|rng: &mut XorShift64, size: usize| {
            let n = rng.next_below(size as u64 + 1) as usize;
            let vals: Vec<f64> = (0..n).map(|_| rng.next_below(10_000) as f64).collect();
            let cut = if n == 0 { 0 } else { rng.next_below(n as u64 + 1) as usize };
            (vals, cut)
        },
        |(vals, cut)| {
            let mut full = PrefixAgg::new();
            for &v in vals {
                full.observe(1, v);
            }
            // replica recovered at `cut`, replays the suffix
            let mut replica = PrefixAgg::new();
            for &v in &vals[..*cut] {
                replica.observe(1, v);
            }
            for &v in &vals[*cut..] {
                replica.observe(1, v);
            }
            let _ = replica.merge(&full);
            if replica != full {
                return Err("replayed replica != full state".to_string());
            }
            Ok(())
        },
    );
}

// ---- WindowRing ≡ BTreeMap differential (PR 8 arena/ring layout) -------
//
// The ring window store replaced `BTreeMap<WindowId, C>` inside every
// windowed container. Its contract is *observational equivalence*: any
// op schedule the engine can produce — in-horizon touches, late
// re-inserts below the dense base, far-future spills past
// MAX_DENSE_SPAN, compaction floors, removes — must leave the ring and
// a BTreeMap model with identical ascending iteration and
// byte-identical `Encode` output. These properties are what lets the
// swap ship without a wire/checkpoint format bump.

/// One step of a window-store op schedule: `(kind, window, value)`.
type RingOp = (u64, u64, u64);

fn gen_ring_ops(rng: &mut XorShift64, size: usize) -> Vec<RingOp> {
    let n = rng.next_below(3 * size as u64 + 1);
    (0..n)
        .map(|_| {
            // mostly a dense working set; occasionally a far window that
            // must overflow the ring's dense span into the spill map
            let w = if rng.chance(0.08) {
                1500 + rng.next_below(4000)
            } else {
                rng.next_below(48)
            };
            (rng.next_below(10), w, 1 + rng.next_below(100))
        })
        .collect()
}

#[test]
fn window_ring_matches_btreemap_under_random_op_schedules() {
    forall(
        "ring vs btreemap model",
        200,
        48,
        &gen_ring_ops,
        |ops: &Vec<RingOp>| {
            let mut ring: WindowRing<u64> = WindowRing::new();
            let mut model: BTreeMap<WindowId, u64> = BTreeMap::new();
            let mut floor = 0u64; // compaction floors are monotone in the engine
            for &(kind, w, v) in ops {
                match kind {
                    0..=4 => {
                        *ring.entry_or_insert_with(w, || 0) += v;
                        *model.entry(w).or_insert(0) += v;
                    }
                    5 | 6 => {
                        let r = ring.insert(w, v);
                        let m = model.insert(w, v);
                        if r != m {
                            return Err(format!("insert({w}) returned {r:?}, model {m:?}"));
                        }
                    }
                    7 => {
                        let r = ring.remove(&w);
                        let m = model.remove(&w);
                        if r != m {
                            return Err(format!("remove({w}) returned {r:?}, model {m:?}"));
                        }
                    }
                    8 => {
                        floor = floor.max(w);
                        ring.compact_below(floor);
                        model.retain(|&k, _| k >= floor);
                    }
                    _ => {
                        if ring.get(&w) != model.get(&w) {
                            return Err(format!(
                                "get({w}): ring {:?}, model {:?}",
                                ring.get(&w),
                                model.get(&w)
                            ));
                        }
                    }
                }
            }
            if ring.len() != model.len() {
                return Err(format!("len: ring {}, model {}", ring.len(), model.len()));
            }
            let rs: Vec<(WindowId, u64)> = ring.iter().map(|(w, v)| (w, *v)).collect();
            let ms: Vec<(WindowId, u64)> = model.iter().map(|(&w, &v)| (w, v)).collect();
            if rs != ms {
                return Err(format!("iteration diverged: ring {rs:?}, model {ms:?}"));
            }
            let mut wr = Writer::new();
            ring.encode(&mut wr);
            let mut wm = Writer::new();
            model.encode(&mut wm);
            if wr.as_slice() != wm.as_slice() {
                return Err("ring encode is not byte-identical to BTreeMap".to_string());
            }
            // decode round-trip: a fresh ring anchored by the decoded
            // keys must still compare equal (logical PartialEq) and
            // re-encode to the same bytes (canonical layout)
            let back = WindowRing::<u64>::from_bytes(wr.as_slice())
                .map_err(|e| format!("decode failed: {e:?}"))?;
            if back != ring {
                return Err("decode round-trip changed the ring".to_string());
            }
            let mut wb = Writer::new();
            back.encode(&mut wb);
            if wb.as_slice() != wr.as_slice() {
                return Err("re-encode after decode is not byte-stable".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn wcrdt_ring_delta_join_is_byte_identical_to_full_state() {
    // Replica A applies an op schedule directly; replica B is built only
    // from A's deltas (a full cut, then an incremental cut). The ring
    // layouts grow along very different paths — A anchors at the first
    // inserted window, B at whatever the first delta carried — yet the
    // encoded states must match byte-for-byte: physical ring geometry
    // must never leak into the wire/checkpoint format.
    forall(
        "wcrdt ring delta bytes",
        80,
        32,
        &|rng: &mut XorShift64, size: usize| {
            let parts = 2 + rng.next_below(3) as u32;
            let n = 1 + rng.next_below(size as u64 + 1);
            let ops: Vec<(u32, u64, u64)> = (0..n)
                .map(|_| {
                    (
                        rng.next_below(parts as u64) as u32,
                        rng.next_below(8_000),
                        1 + rng.next_below(5),
                    )
                })
                .collect();
            let cut = rng.next_below(n + 1) as usize;
            (parts, ops, cut)
        },
        |(parts, ops, cut)| {
            let mk = || -> WindowedCrdt<GCounter> {
                WindowedCrdt::new(WindowAssigner::tumbling(1000), 0..*parts)
            };
            let mut a = mk();
            let mut b = mk();
            for &(p, ts, n) in &ops[..*cut] {
                a.insert_with(p, ts, |c| c.add(p as u64, n))
                    .map_err(|e| e.to_string())?;
            }
            let _ = b.merge(&a.take_delta()); // everything so far is dirty
            for &(p, ts, n) in &ops[*cut..] {
                a.insert_with(p, ts, |c| c.add(p as u64, n))
                    .map_err(|e| e.to_string())?;
            }
            for p in 0..*parts {
                a.increment_watermark(p, 9_000);
            }
            let _ = b.merge(&a.take_delta());
            if b != a {
                return Err("delta join diverged from full state".to_string());
            }
            if b.to_bytes() != a.to_bytes() {
                return Err("states equal but encodes differ: ring layout leaked".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn ring_backed_replicas_reencode_byte_identically_under_faults() {
    // Fault-schedule-level differential: run the canonical Query1
    // workload under a generated kill/restart/partition/burst plan
    // (twice), and require (a) the ring-backed engine is still
    // deterministic — byte-identical deduped outputs and harvested
    // replicas across runs — and (b) every harvested replica, whose
    // ring grew through an arbitrary fault-shaped insert/merge/compact
    // history, decodes and re-encodes to the exact harvested bytes.
    // Together with the model properties above this pins that swapping
    // BTreeMap for WindowRing changed no wire or checkpoint byte.
    use holon::nexmark::queries::Query1;
    use holon::sim::{check_exactly_once, run_plan_with, FaultPlan, SimSpec};

    let spec = SimSpec { seed: 91, ..SimSpec::default() };
    let plan = FaultPlan::generate(91, spec.nodes, spec.fault_window());
    let a = run_plan_with(&spec, &plan, None, Query1::new(spec.window_ms));
    let b = run_plan_with(&spec, &plan, None, Query1::new(spec.window_ms));
    if let Err(f) = check_exactly_once(&a) {
        panic!("faulty run violated exactly-once: {f}");
    }
    assert_eq!(a.deduped, b.deduped, "ring store broke run determinism");
    assert_eq!(a.replicas, b.replicas, "harvested replicas diverged");
    assert!(!a.replicas.is_empty(), "no replicas harvested (vacuous test)");
    for (node, bytes) in &a.replicas {
        let w = WindowedCrdt::<GCounter>::from_bytes(bytes)
            .unwrap_or_else(|e| panic!("node {node}: replica decode failed: {e:?}"));
        assert_eq!(&w.to_bytes(), bytes, "node {node}: re-encode differs");
    }
}
