//! Ablations over the design choices DESIGN.md calls out: gossip mode
//! (full-state vs delta, §7), gossip interval, gossip fan-out, and
//! batch size — each swept on the Q7 failure-free workload.

mod common;

use holon::benchkit::{row, section};
use holon::experiments::{run_holon, Workload};

fn main() {
    let base = {
        let mut cfg = common::failure_cfg();
        cfg.duration_ms = 20_000;
        cfg
    };

    section("Ablation: gossip payload mode (full vs delta, paper §7)");
    for (name, delta) in [("full-state", false), ("delta+anti-entropy", true)] {
        let mut cfg = base.clone();
        cfg.gossip_delta = delta;
        let r = run_holon(&cfg, Workload::Q7, vec![]);
        row(
            name,
            &[
                ("avg_latency_ms", format!("{:.0}", r.latency_mean_ms)),
                ("p99_ms", r.latency_p99_ms.to_string()),
                ("outputs", r.outputs.to_string()),
            ],
        );
    }

    section("Ablation: gossip interval (latency floor vs sync traffic)");
    for interval in [25u64, 50, 100, 200, 400] {
        let mut cfg = base.clone();
        cfg.gossip_interval_ms = interval;
        let r = run_holon(&cfg, Workload::Q7, vec![]);
        row(
            &format!("{interval} ms"),
            &[
                ("avg_latency_ms", format!("{:.0}", r.latency_mean_ms)),
                ("p99_ms", r.latency_p99_ms.to_string()),
            ],
        );
    }

    section("Ablation: gossip fan-out (convergence depth)");
    for fanout in [0u32, 2, 4, 8] {
        let mut cfg = base.clone();
        cfg.gossip_fanout = fanout;
        let r = run_holon(&cfg, Workload::Q7, vec![]);
        row(
            &(if fanout == 0 {
                "broadcast".to_string()
            } else {
                format!("fanout {fanout}")
            }),
            &[
                ("avg_latency_ms", format!("{:.0}", r.latency_mean_ms)),
                ("p99_ms", r.latency_p99_ms.to_string()),
            ],
        );
    }

    section("Ablation: run-loop batch size");
    for batch in [64usize, 256, 1024, 4096] {
        let mut cfg = base.clone();
        cfg.batch_size = batch;
        let r = run_holon(&cfg, Workload::Q7, vec![]);
        row(
            &format!("batch {batch}"),
            &[
                ("avg_latency_ms", format!("{:.0}", r.latency_mean_ms)),
                ("p99_ms", r.latency_p99_ms.to_string()),
                ("consumed", r.consumed.to_string()),
            ],
        );
    }
}
