//! Figure 7 — latency sensitivity curves for concurrent failures: the
//! latency-over-time curve of each system under the concurrent-failure
//! scenario, against its own failure-free baseline. The sensitivity is
//! the area between the two curves (Gramoli et al.).

mod common;

use common::{failure_cfg, FAILURE_T0};
use holon::benchkit::{row, section, sparkline};
use holon::experiments::{run_flink, run_holon, Scenario, Workload};

fn main() {
    let cfg = failure_cfg();
    section("Figure 7 — sensitivity curves (concurrent failures at t=20s)");

    let holon_base = run_holon(&cfg, Workload::Q7, vec![]);
    let holon_fail = run_holon(
        &cfg,
        Workload::Q7,
        Scenario::ConcurrentFailures.schedule(FAILURE_T0),
    );
    let flink_base = run_flink(&cfg, Workload::Q7, false, vec![]);
    let flink_fail = run_flink(
        &cfg,
        Workload::Q7,
        false,
        Scenario::ConcurrentFailures.schedule(FAILURE_T0),
    );

    // excess-latency curves (failure minus baseline; outages age)
    for (name, fail, base) in [
        ("Holon", &holon_fail, &holon_base),
        ("Flink (model)", &flink_fail, &flink_base),
    ] {
        // skip the 10 s startup transient, as sensitivity_vs does
        let excess = holon::metrics::excess_series(
            &fail.latency_series[20.min(fail.latency_series.len())..],
            &base.latency_series[20.min(base.latency_series.len())..],
            common::BUCKET_MS,
        );
        println!("{name:<16} excess latency {}", sparkline(&excess));
        let curve: Vec<String> = excess
            .iter()
            .step_by(4)
            .map(|v| format!("{:.0}", v))
            .collect();
        println!("{name:<16} excess_ms[2s] {}", curve.join(","));
    }

    let s_holon = holon_fail.sensitivity_vs(&holon_base);
    let s_flink = flink_fail.sensitivity_vs(&flink_base);
    row(
        "sensitivity (area, s^2)",
        &[
            ("holon", format!("{s_holon:.2}")),
            ("flink", format!("{s_flink:.2}")),
            (
                "flink/holon",
                format!("{:.0}x", s_flink / s_holon.max(1e-9)),
            ),
        ],
    );
}
