//! Table 2 — latency comparison (seconds) under failure scenarios:
//! Avg and P99 for Holon, Flink and Flink-with-spare-slots, in the
//! Baseline / Concurrent / Subsequent / Crash scenarios.
//!
//! Paper shape: Holon ~5× lower avg latency at baseline, ≥ 11× under
//! failures; plain Flink has no entry for Crash (it stalls); spare
//! slots recover Flink's crash case but stay well above Holon.

mod common;

use common::{failure_cfg, FAILURE_T0};
use holon::benchkit::{secs, section};
use holon::experiments::{run_flink, run_holon, RunResult, Scenario, Workload};
#[allow(unused_imports)]
use holon::benchkit::row;

fn cell(r: &RunResult) -> String {
    if r.stalled {
        // the paper's "–": the job stopped making progress
        return "    - /     -".to_string();
    }
    format!("{:>5} / {:>5}", secs(r.latency_mean_ms), secs(r.latency_p99_ms as f64))
}

fn main() {
    let cfg = failure_cfg();
    section("Table 2 — latency (avg s / p99 s) per failure scenario");
    println!(
        "{:<22} {:>14} {:>14} {:>14} {:>14}",
        "System", "Baseline", "Concurrent", "Subsequent", "Crash"
    );

    let mut rows: Vec<(String, Vec<String>)> = Vec::new();

    // Holon row
    let mut cells = Vec::new();
    for sc in Scenario::all() {
        let r = run_holon(&cfg, Workload::Q7, sc.schedule(FAILURE_T0));
        cells.push(cell(&r));
    }
    rows.push(("Holon".to_string(), cells));

    // Flink row (plain: crash stalls -> "-")
    let mut cells = Vec::new();
    for sc in Scenario::all() {
        let r = run_flink(&cfg, Workload::Q7, false, sc.schedule(FAILURE_T0));
        cells.push(cell(&r));
    }
    rows.push(("Flink (model)".to_string(), cells));

    // Flink with spare slots
    let mut cells = Vec::new();
    for sc in Scenario::all() {
        let r = run_flink(&cfg, Workload::Q7, true, sc.schedule(FAILURE_T0));
        cells.push(cell(&r));
    }
    rows.push(("Flink (Spare Slots)".to_string(), cells));

    for (name, cells) in &rows {
        println!(
            "{:<22} {:>14} {:>14} {:>14} {:>14}",
            name, cells[0], cells[1], cells[2], cells[3]
        );
    }
}
