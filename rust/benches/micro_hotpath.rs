//! Hot-path micro benchmarks (the §Perf working set): CRDT merges,
//! WCRDT gossip encode/join, log append/read, and the batch aggregators
//! (scalar vs AOT XLA kernel). These are the numbers the perf pass in
//! EXPERIMENTS.md §Perf iterates on.

use holon::api::{BatchAggregator, ScalarAggregator};
use holon::benchkit::{bench, section};
use holon::clock::SimClock;
use holon::codec::{Decode, Encode};
use holon::crdt::{BoundedTopK, Crdt, GCounter, MapCrdt, PrefixAgg};
use holon::log::LogBroker;
use holon::runtime::{XlaMergeKernel, XlaWindowAggregator, MERGE_COLS, MERGE_ROWS};
use holon::util::XorShift64;
use holon::wcrdt::{WindowAssigner, WindowedCrdt};

fn main() {
    section("micro: CRDT merge");
    let mut rng = XorShift64::new(7);
    let mut a = GCounter::new();
    let mut b = GCounter::new();
    for p in 0..50u64 {
        a.add(p, rng.next_below(1000));
        b.add(p, rng.next_below(1000));
    }
    bench("gcounter_merge_50_contributors", 100, 10_000, || {
        let mut x = a.clone();
        x.merge(&b);
        std::hint::black_box(&x);
    });

    let mut ta = BoundedTopK::new(10);
    let mut tb = BoundedTopK::new(10);
    for i in 0..200 {
        ta.offer(rng.next_f64() * 1000.0, i, i % 8);
        tb.offer(rng.next_f64() * 1000.0, i + 200, i % 8);
    }
    bench("topk10_merge", 100, 10_000, || {
        let mut x = ta.clone();
        x.merge(&tb);
        std::hint::black_box(&x);
    });

    section("micro: WCRDT gossip path (encode + decode + join)");
    let mut w: WindowedCrdt<MapCrdt<u64, PrefixAgg>> =
        WindowedCrdt::new(WindowAssigner::tumbling(1000), 0..50);
    for t in 0..16_000u64 {
        let p = (t % 50) as u32;
        let _ = w.insert_with(p, t, |m| m.entry(t % 10).observe(p as u64, 1.0));
    }
    let bytes = w.to_bytes();
    println!("gossip payload: {} bytes ({} windows live)", bytes.len(), w.live_windows());
    bench("wcrdt_encode", 10, 2_000, || {
        std::hint::black_box(w.to_bytes());
    });
    bench("wcrdt_decode", 10, 2_000, || {
        std::hint::black_box(
            WindowedCrdt::<MapCrdt<u64, PrefixAgg>>::from_bytes(&bytes).unwrap(),
        );
    });
    let other = w.clone();
    bench("wcrdt_join", 10, 2_000, || {
        let mut x = w.clone();
        x.merge(&other);
        std::hint::black_box(&x);
    });

    section("micro: logged stream");
    let clock = SimClock::manual();
    let broker = LogBroker::new(clock);
    let topic = broker.topic("bench", 1);
    let payload = vec![0u8; 64];
    bench("log_append_64B", 1000, 200_000, || {
        topic.append(0, 1, payload.clone());
    });
    bench("log_read_batch_256", 10, 5_000, || {
        let (recs, _) = topic.read(0, 0, 256);
        std::hint::black_box(recs);
    });
    // the zero-copy RUN_BATCH path vs the copying read above: same
    // records, no Vec<Record> materialization, no payload Arc bumps
    bench("log_read_slice_256", 10, 5_000, || {
        let (n, _) = topic.read_slice(0, 0, 256, |recs| {
            let mut sum = 0u64;
            for r in recs {
                sum += r.payload.len() as u64;
            }
            sum
        });
        std::hint::black_box(n);
    });

    section("micro: checkpoint encode (nested single-pass vs two-pass)");
    let ckpt_local = (0u64..64).collect::<Vec<u64>>();
    bench("ckpt_encode_two_pass", 100, 10_000, || {
        // the pre-overhaul shape: encode to an intermediate Vec, then
        // length-prefix copy it into the outer writer
        let mut outer = holon::codec::Writer::new();
        outer.put_bytes(&ckpt_local.to_bytes());
        outer.put_bytes(&w.to_bytes());
        std::hint::black_box(outer.into_bytes());
    });
    bench("ckpt_encode_nested", 100, 10_000, || {
        let mut outer = holon::codec::Writer::new();
        outer.put_nested(|o| ckpt_local.encode(o));
        outer.put_nested(|o| w.encode(o));
        std::hint::black_box(outer.into_bytes());
    });

    section("micro: batch aggregation (1024 events, 4 windows)");
    let items: Vec<(f64, u64)> = (0..1024)
        .map(|i| (((i * 37) % 9999) as f64, (i % 4) as u64))
        .collect();
    let mut scalar = ScalarAggregator;
    bench("scalar_aggregate_1024", 100, 10_000, || {
        std::hint::black_box(scalar.aggregate(&items));
    });

    // Many-window batches (keyed queries like Q4 put window × key
    // segments in one batch): the case where the old O(items × windows)
    // linear scan collapsed and the hash-map group-by shines.
    section("micro: batch aggregation (4096 events, 512 windows)");
    let wide: Vec<(f64, u64)> = (0..4096)
        .map(|i| (((i * 37) % 9999) as f64, (i % 512) as u64))
        .collect();
    bench("scalar_aggregate_4096_512w", 50, 5_000, || {
        std::hint::black_box(scalar.aggregate(&wide));
    });
    match XlaWindowAggregator::load(std::path::Path::new("artifacts")) {
        Ok(mut xla) => {
            bench("xla_aggregate_1024", 20, 500, || {
                std::hint::black_box(xla.aggregate(&items));
            });
            println!("xla kernel calls: {}", xla.calls());
        }
        Err(e) => println!("xla aggregate skipped: {e} (run `make artifacts`)"),
    }

    section("micro: CRDT merge kernel (XLA, 64x128 f32)");
    match XlaMergeKernel::load(std::path::Path::new("artifacts")) {
        Ok(kernel) => {
            let a: Vec<f32> = (0..MERGE_ROWS * MERGE_COLS).map(|i| i as f32).collect();
            let b: Vec<f32> = a.iter().rev().copied().collect();
            bench("xla_crdt_merge_64x128", 20, 500, || {
                std::hint::black_box(kernel.merge(&a, &b).unwrap());
            });
            // scalar reference for the same join
            bench("scalar_crdt_merge_64x128", 100, 10_000, || {
                let m: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x.max(*y)).collect();
                std::hint::black_box(m);
            });
        }
        Err(e) => println!("xla merge skipped: {e}"),
    }
}
