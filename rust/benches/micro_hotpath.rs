//! Hot-path micro benchmarks (the §Perf working set): CRDT merges,
//! WCRDT gossip encode/join, log append/read, and the batch aggregators
//! (scalar vs AOT XLA kernel). These are the numbers the perf pass in
//! EXPERIMENTS.md §Perf iterates on.

// lint:allow-file(discarded-merge): benchmark bodies discard outcomes by design — timing is the observable
use holon::api::{BatchAggregator, ScalarAggregator};
use holon::benchkit::{bench, section};
use holon::clock::SimClock;
use holon::codec::{Decode, DecodeResult, Encode, Reader, Writer};
use holon::crdt::{BoundedTopK, Crdt, GCounter, MapCrdt, PrefixAgg};
use holon::log::LogBroker;
use holon::runtime::{XlaMergeKernel, XlaWindowAggregator, MERGE_COLS, MERGE_ROWS};
use holon::shard::ShardedMapCrdt;
use holon::util::XorShift64;
use holon::wcrdt::{WindowAssigner, WindowRing, WindowedCrdt};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counting allocator: every heap allocation (and growth) in the bench
/// process bumps `ALLOCS`. Sections measure straight-line deltas, which
/// is what lets this binary *assert* the arena/ring allocation
/// contracts instead of eyeballing throughput numbers.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Key whose clones are counted — the observable side of the
/// `MapCrdt::merge` probe-before-clone fix (merge used to clone every
/// key of `other` per merge, present or not).
static KEY_CLONES: AtomicU64 = AtomicU64::new(0);

#[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
struct CountKey(u64);

impl Clone for CountKey {
    fn clone(&self) -> Self {
        KEY_CLONES.fetch_add(1, Ordering::Relaxed);
        CountKey(self.0)
    }
}

impl Encode for CountKey {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.0);
    }
}

impl Decode for CountKey {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(CountKey(r.get_u64()?))
    }
}

fn main() {
    section("micro: CRDT merge");
    let mut rng = XorShift64::new(7);
    let mut a = GCounter::new();
    let mut b = GCounter::new();
    for p in 0..50u64 {
        a.add(p, rng.next_below(1000));
        b.add(p, rng.next_below(1000));
    }
    bench("gcounter_merge_50_contributors", 100, 10_000, || {
        let mut x = a.clone();
        let _ = x.merge(&b);
        std::hint::black_box(&x);
    });

    let mut ta = BoundedTopK::new(10);
    let mut tb = BoundedTopK::new(10);
    for i in 0..200 {
        ta.offer(rng.next_f64() * 1000.0, i, i % 8);
        tb.offer(rng.next_f64() * 1000.0, i + 200, i % 8);
    }
    bench("topk10_merge", 100, 10_000, || {
        let mut x = ta.clone();
        let _ = x.merge(&tb);
        std::hint::black_box(&x);
    });

    section("micro: MapCrdt merge key-clone accounting");
    // steady-state merge (every key already present): the probe-before-
    // clone fast path must not clone a single key
    let build_counted = |keys: std::ops::Range<u64>| {
        let mut m: MapCrdt<CountKey, GCounter> = MapCrdt::new();
        for k in keys {
            m.entry(CountKey(k)).add(k % 8, k + 1);
        }
        m
    };
    let mut warm = build_counted(0..512);
    let incoming = build_counted(0..512);
    let before = KEY_CLONES.load(Ordering::Relaxed);
    let _ = warm.merge(&incoming);
    let clones = KEY_CLONES.load(Ordering::Relaxed) - before;
    assert_eq!(clones, 0, "existing-key merge must clone zero keys (was 512/merge pre-fix)");
    println!("steady-state merge of 512 present keys: {clones} key clones (pre-fix: 512)");
    let fresh = build_counted(512..640);
    let before = KEY_CLONES.load(Ordering::Relaxed);
    let _ = warm.merge(&fresh);
    let clones = KEY_CLONES.load(Ordering::Relaxed) - before;
    assert_eq!(clones, 128, "only genuinely new keys may clone");
    println!("merge introducing 128 new keys: {clones} key clones");

    let mut ma = MapCrdt::<u64, GCounter>::new();
    let mut mb = MapCrdt::<u64, GCounter>::new();
    for k in 0..4096u64 {
        ma.entry(k).add(k % 8, k + 1);
        mb.entry(k).add((k + 1) % 8, k + 2);
    }
    bench("map_merge_4096_existing_keys", 20, 2_000, || {
        let mut x = ma.clone();
        let _ = x.merge(&mb);
        std::hint::black_box(&x);
    });

    section("micro: sharded keyed state (8 shards, 4096 keys)");
    let build_sharded = |shards: u32, salt: u64| {
        let mut m: ShardedMapCrdt<u64, PrefixAgg> = ShardedMapCrdt::with_shards(shards);
        for k in 0..4096u64 {
            m.entry(k).observe(k % 8, (k + salt) as f64);
        }
        m
    };
    let sa = build_sharded(8, 1);
    let sb = build_sharded(8, 2);
    bench("sharded_map_merge_8x4096", 20, 2_000, || {
        let mut x = sa.clone();
        let _ = x.merge(&sb);
        std::hint::black_box(&x);
    });
    // flat baseline with the SAME per-iteration work shape as the
    // sharded bench above (one clone, merge of two distinct states) so
    // the pair isolates the sharding layer
    let build_flat = |salt: u64| {
        let mut m: MapCrdt<u64, PrefixAgg> = MapCrdt::new();
        for k in 0..4096u64 {
            m.entry(k).observe(k % 8, (k + salt) as f64);
        }
        m
    };
    let fa = build_flat(1);
    let fb = build_flat(2);
    bench("flat_map_merge_4096_oracle", 20, 2_000, || {
        let mut x = fa.clone();
        let _ = x.merge(&fb);
        std::hint::black_box(&x);
    });
    // delta encode: one dirty shard out of 8 vs the full map
    let mut delta_src = build_sharded(8, 3);
    let _ = delta_src.take_delta(); // drain construction dirt
    delta_src.entry(17).observe(0, 1.0);
    let delta = delta_src.take_delta();
    println!(
        "delta payload: {} B (1 dirty shard) vs full state {} B",
        delta.to_bytes().len(),
        delta_src.to_bytes().len()
    );
    bench("sharded_delta_encode_1_of_8", 50, 5_000, || {
        std::hint::black_box(delta.to_bytes());
    });

    section("micro: query read path (scan paths clone zero keys)");
    {
        use holon::query::QueryEngine;
        // Flat state: signing every window key plus an absent-key point
        // lookup must not clone a single key — `MapCrdt::iter` and the
        // scanner's `for_each` walk by reference.
        let mut wq: WindowedCrdt<MapCrdt<CountKey, GCounter>> =
            WindowedCrdt::new(WindowAssigner::tumbling(1000), [0u32].iter().copied());
        for k in 0..4096u64 {
            let _ = wq.insert_with(0, 100, |m| m.entry(CountKey(k)).add(k % 8, k + 1));
        }
        wq.increment_watermark(0, 1000);
        let before = KEY_CLONES.load(Ordering::Relaxed);
        let mut q = QueryEngine::new(wq); // signs all 4096 keys
        let miss = q.point(0, &CountKey(999_999_999), 0).unwrap();
        assert!(miss.value.is_none());
        let clones = KEY_CLONES.load(Ordering::Relaxed) - before;
        assert_eq!(clones, 0, "flat sign + absent point lookup must clone zero keys");
        println!("flat sign_into(4096 keys) + point miss: {clones} key clones");

        // A range scan visits all 4096 rows but may only clone the rows
        // it returns.
        let before = KEY_CLONES.load(Ordering::Relaxed);
        let r = q.range(0, &CountKey(10), &CountKey(13), 0).unwrap();
        assert_eq!(r.value.len(), 4);
        let clones = KEY_CLONES.load(Ordering::Relaxed) - before;
        assert_eq!(clones, 4, "range must clone returned rows only, not scanned rows");
        println!("range 4 of 4096 rows: {clones} key clones");
        bench("query_range_4_of_4096", 50, 5_000, || {
            std::hint::black_box(q.range(0, &CountKey(10), &CountKey(13), 0).unwrap().value.len());
        });

        // Sharded state: `entries()` (the scanner's traversal) and
        // `sign_into` across 8 shards are reference walks too.
        let mut ws: WindowedCrdt<ShardedMapCrdt<CountKey, GCounter>> =
            WindowedCrdt::new(WindowAssigner::tumbling(1000), [0u32].iter().copied());
        for k in 0..4096u64 {
            let _ = ws.insert_with(0, 100, |m| {
                m.ensure_shards(8);
                m.entry(CountKey(k)).add(k % 8, 1);
            });
        }
        ws.increment_watermark(0, 1000);
        let before = KEY_CLONES.load(Ordering::Relaxed);
        let qs = QueryEngine::new(ws); // per-shard sign_into
        let n = qs.state().raw_window(0).unwrap().entries().count();
        assert_eq!(n, 4096);
        let clones = KEY_CLONES.load(Ordering::Relaxed) - before;
        assert_eq!(clones, 0, "sharded sign + entries() traversal must clone zero keys");
        println!("sharded sign_into(8x512) + entries() walk: {clones} key clones");
    }

    section("micro: WCRDT gossip path (encode + decode + join)");
    let mut w: WindowedCrdt<MapCrdt<u64, PrefixAgg>> =
        WindowedCrdt::new(WindowAssigner::tumbling(1000), 0..50);
    for t in 0..16_000u64 {
        let p = (t % 50) as u32;
        let _ = w.insert_with(p, t, |m| m.entry(t % 10).observe(p as u64, 1.0));
    }
    let bytes = w.to_bytes();
    println!("gossip payload: {} bytes ({} windows live)", bytes.len(), w.live_windows());
    bench("wcrdt_encode", 10, 2_000, || {
        std::hint::black_box(w.to_bytes());
    });
    bench("wcrdt_decode", 10, 2_000, || {
        std::hint::black_box(
            WindowedCrdt::<MapCrdt<u64, PrefixAgg>>::from_bytes(&bytes).unwrap(),
        );
    });
    let other = w.clone();
    bench("wcrdt_join", 10, 2_000, || {
        let mut x = w.clone();
        let _ = x.merge(&other);
        std::hint::black_box(&x);
    });

    section("micro: arena output path (4096-frame batch, ≤1 alloc)");
    {
        use holon::arena::OutputArena;
        let mut arena = OutputArena::new();
        let emit_batch = |arena: &mut OutputArena| {
            for i in 0..4096u64 {
                arena.frame(i, |w| {
                    w.put_u64(i);
                    w.put_f64(i as f64);
                    true
                });
            }
        };
        // warmup batch establishes the high-water pre-reserve and the
        // frame-table capacity (recycled after shipping)
        arena.begin_batch();
        emit_batch(&mut arena);
        let warm = arena.finish(0).unwrap();
        arena.recycle(warm);
        // steady state: the whole batch costs at most one backing
        // allocation (the begin_batch pre-reserve); the 4096-frame emit
        // loop itself performs ZERO heap allocations
        arena.begin_batch();
        let before = allocs();
        emit_batch(&mut arena);
        let during = allocs() - before;
        assert!(
            arena.batch_allocs() <= 1,
            "arena backing grew {} times in one batch (contract: ≤1)",
            arena.batch_allocs()
        );
        assert_eq!(during, 0, "4096-frame emit loop allocated {during} times (contract: 0)");
        println!("4096-frame batch: {} backing allocs, {during} emit-loop allocs", arena.batch_allocs());
        // ship it as shared views: the read side clones zero payloads
        let clock2 = SimClock::manual();
        let broker2 = LogBroker::new(clock2);
        let out = broker2.topic("arena-out", 1);
        let batch = arena.finish(0).unwrap();
        out.append_frames(0, &batch);
        arena.recycle(batch);
        let (n, _) = out.read_slice(0, 0, 4096, |recs| {
            let mut sum = 0usize;
            for r in recs {
                sum += r.payload.len();
            }
            sum
        });
        std::hint::black_box(n);
        let (clones, read) = out.read_stats();
        assert_eq!(read, 4096);
        assert_eq!(clones, 0, "arena-batch drain must clone zero payloads");
        println!("drained {read} arena-framed records: {clones} payload clones");
        // The engine's emit loop now carries flight-recorder call sites
        // inline (holon::trace overhead contract): with tracing disabled
        // the same 4096-frame loop must STILL allocate zero times — a
        // disabled record call is one predicted branch, nothing else.
        let trace = holon::trace::TraceHandle::disabled(0);
        arena.begin_batch();
        let before = allocs();
        for i in 0..4096u64 {
            arena.frame(i, |w| {
                w.put_u64(i);
                w.put_f64(i as f64);
                true
            });
            trace.record(i, holon::trace::TraceKind::WindowEmitted, i, 1, 16);
        }
        let during = allocs() - before;
        assert_eq!(
            during, 0,
            "disabled tracing allocated {during} times in the emit loop (contract: 0)"
        );
        println!("4096-frame emit loop with disabled trace call sites: {during} allocs");
        let b = arena.finish(0).unwrap();
        arena.recycle(b);
        bench("arena_emit_4096_frames", 20, 2_000, || {
            arena.begin_batch();
            emit_batch(&mut arena);
            let b = arena.finish(0).unwrap();
            std::hint::black_box(&b);
            arena.recycle(b);
        });
    }

    section("micro: window ring (zero per-insert allocs in horizon)");
    {
        let mut ring: WindowRing<u64> = WindowRing::new();
        // warm the 16-window live horizon (the compaction span)
        for w in 0..16u64 {
            *ring.entry_or_insert_with(w, || 0) += 1;
        }
        let before = allocs();
        for i in 0..4096u64 {
            *ring.entry_or_insert_with(i % 16, || 0) += 1;
        }
        let during = allocs() - before;
        assert_eq!(during, 0, "in-horizon ring inserts allocated {during} times (contract: 0)");
        assert_eq!(ring.spilled(), 0);
        println!("4096 in-horizon ring touches: {during} allocs, {} spills", ring.spilled());
        bench("window_ring_touch_4096_in_horizon", 100, 10_000, || {
            for i in 0..4096u64 {
                *ring.entry_or_insert_with(i % 16, || 0) += 1;
            }
            std::hint::black_box(&ring);
        });
        // the structure this replaced, same touch pattern
        let mut bt: std::collections::BTreeMap<u64, u64> = (0..16u64).map(|w| (w, 1)).collect();
        bench("btreemap_touch_4096_in_horizon", 100, 10_000, || {
            for i in 0..4096u64 {
                *bt.entry(i % 16).or_insert(0) += 1;
            }
            std::hint::black_box(&bt);
        });
    }

    section("micro: flight recorder + stage-latency histogram");
    {
        use holon::metrics::LatencyHistogram;
        use holon::trace::{TraceHandle, TraceKind, Tracer, DEFAULT_RING_CAP};
        // atomic-bucket record: the per-output hot path of the sink and
        // the per-batch path of the nodes
        let h = LatencyHistogram::new();
        bench("latency_histogram_record", 1000, 200_000, || {
            h.record(std::hint::black_box(37));
        });
        // disabled trace record: one predicted branch, zero allocations
        let disabled = TraceHandle::disabled(0);
        let before = allocs();
        for i in 0..100_000u64 {
            disabled.record(i, TraceKind::GossipRound, i, 0, 0);
        }
        assert_eq!(
            allocs() - before,
            0,
            "disabled trace records must not allocate"
        );
        bench("trace_record_disabled", 1000, 200_000, || {
            disabled.record(1, TraceKind::GossipRound, 1, 0, 0);
        });
        // enabled record into a warmed ring: a mutex lock + array write
        // (the ring never grows past its pre-allocated capacity)
        let tracer = Tracer::new(DEFAULT_RING_CAP);
        let live = tracer.handle(0);
        bench("trace_record_enabled_ring", 200, 100_000, || {
            live.record(1, TraceKind::GossipRound, 1, 0, 0);
        });
    }

    section("micro: logged stream");
    let clock = SimClock::manual();
    let broker = LogBroker::new(clock);
    let topic = broker.topic("bench", 1);
    let payload = vec![0u8; 64];
    bench("log_append_64B", 1000, 200_000, || {
        topic.append(0, 1, payload.clone());
    });
    bench("log_read_batch_256", 10, 5_000, || {
        let (recs, _) = topic.read(0, 0, 256);
        std::hint::black_box(recs);
    });
    // the zero-copy RUN_BATCH path vs the copying read above: same
    // records, no Vec<Record> materialization, no payload Arc bumps
    bench("log_read_slice_256", 10, 5_000, || {
        let (n, _) = topic.read_slice(0, 0, 256, |recs| {
            let mut sum = 0u64;
            for r in recs {
                sum += r.payload.len() as u64;
            }
            sum
        });
        std::hint::black_box(n);
    });

    section("micro: checkpoint encode (nested single-pass vs two-pass)");
    let ckpt_local = (0u64..64).collect::<Vec<u64>>();
    bench("ckpt_encode_two_pass", 100, 10_000, || {
        // the pre-overhaul shape: encode to an intermediate Vec, then
        // length-prefix copy it into the outer writer
        let mut outer = holon::codec::Writer::new();
        outer.put_bytes(&ckpt_local.to_bytes());
        outer.put_bytes(&w.to_bytes());
        std::hint::black_box(outer.into_bytes());
    });
    bench("ckpt_encode_nested", 100, 10_000, || {
        let mut outer = holon::codec::Writer::new();
        outer.put_nested(|o| ckpt_local.encode(o));
        outer.put_nested(|o| w.encode(o));
        std::hint::black_box(outer.into_bytes());
    });

    section("micro: batch aggregation (1024 events, 4 windows)");
    let items: Vec<(f64, u64)> = (0..1024)
        .map(|i| (((i * 37) % 9999) as f64, (i % 4) as u64))
        .collect();
    let mut scalar = ScalarAggregator;
    bench("scalar_aggregate_1024", 100, 10_000, || {
        std::hint::black_box(scalar.aggregate(&items));
    });

    // Many-window batches (keyed queries like Q4 put window × key
    // segments in one batch): the case where the old O(items × windows)
    // linear scan collapsed and the hash-map group-by shines.
    section("micro: batch aggregation (4096 events, 512 windows)");
    let wide: Vec<(f64, u64)> = (0..4096)
        .map(|i| (((i * 37) % 9999) as f64, (i % 512) as u64))
        .collect();
    bench("scalar_aggregate_4096_512w", 50, 5_000, || {
        std::hint::black_box(scalar.aggregate(&wide));
    });
    match XlaWindowAggregator::load(std::path::Path::new("artifacts")) {
        Ok(mut xla) => {
            bench("xla_aggregate_1024", 20, 500, || {
                std::hint::black_box(xla.aggregate(&items));
            });
            println!("xla kernel calls: {}", xla.calls());
        }
        Err(e) => println!("xla aggregate skipped: {e} (run `make artifacts`)"),
    }

    section("micro: CRDT merge kernel (XLA, 64x128 f32)");
    match XlaMergeKernel::load(std::path::Path::new("artifacts")) {
        Ok(kernel) => {
            let a: Vec<f32> = (0..MERGE_ROWS * MERGE_COLS).map(|i| i as f32).collect();
            let b: Vec<f32> = a.iter().rev().copied().collect();
            bench("xla_crdt_merge_64x128", 20, 500, || {
                std::hint::black_box(kernel.merge(&a, &b).unwrap());
            });
            // scalar reference for the same join
            bench("scalar_crdt_merge_64x128", 100, 10_000, || {
                let m: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x.max(*y)).collect();
                std::hint::black_box(m);
            });
        }
        Err(e) => println!("xla merge skipped: {e}"),
    }
}
