//! Shared configuration for the paper-reproduction benches.
//!
//! All benches run Q7 on the §5.2 deployment shape (5 nodes, 10
//! partitions) unless stated otherwise, at a sim-time scale that keeps
//! `cargo bench` in the minutes range. Paper constants (checkpoint 5 s,
//! heartbeat 4 s / timeout 6 s, restart 10 s) are kept verbatim in
//! sim-time, so ratios between systems are preserved.

use holon::config::HolonConfig;

/// The §5.2 failure-experiment deployment: Q7 on five nodes.
pub fn failure_cfg() -> HolonConfig {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 5;
    cfg.partitions = 10;
    cfg.events_per_sec_per_partition = 1000;
    cfg.wall_ms_per_sim_sec = 20.0; // 60 sim-s in 1.2 wall-s
    cfg.duration_ms = 60_000;
    cfg.window_ms = 1000;
    cfg
}

/// When the failure scenarios begin (sim-ms into the run).
pub const FAILURE_T0: u64 = 20_000;

/// Bucket width of the latency/throughput series (sim-ms).
pub const BUCKET_MS: u64 = 500;
