//! §5.3 max-throughput experiment: 10 nodes, 50 partitions; the
//! ingestion rate starts at 1k events/s/partition and doubles every two
//! sim-seconds; report the peak sustained consumption rate before the
//! system saturates.
//!
//! Paper shape: Holon ≫ Flink on Q4 (11×: the keyed global aggregation
//! without shuffles vs per-record shuffle + tree) and moderately ahead
//! on Q7 (1.8×).

mod common;

use holon::benchkit::{ratio, row, section};
use holon::config::HolonConfig;
use holon::experiments::{run_max_throughput, Workload};

fn cfg() -> HolonConfig {
    let mut cfg = HolonConfig::default();
    // scaled-down deployment (single-core host): 5 nodes, 25 partitions;
    // modeled per-event service costs are calibrated from the paper's
    // measured per-node throughput, so the saturation *ratio* carries.
    cfg.nodes = 5;
    cfg.partitions = 25;
    cfg.events_per_sec_per_partition = 400; // ramp start (doubles every 2 s)
    cfg.wall_ms_per_sim_sec = 200.0; // slow sim: host must outrun both systems
    cfg.duration_ms = 20_000; // 8 doublings + saturation plateau
    cfg.window_ms = 1000;
    cfg.batch_size = 2048;
    cfg
}

fn main() {
    section("§5.3 max throughput — 5 nodes, 25 partitions, exponentially ramped input");
    for workload in [Workload::Q4, Workload::Q7] {
        let holon = run_max_throughput(&cfg(), workload, true);
        let flink = run_max_throughput(&cfg(), workload, false);
        row(
            &format!("{workload:?}"),
            &[
                ("holon_peak_ev_s", format!("{:.0}", holon.peak_throughput)),
                ("flink_peak_ev_s", format!("{:.0}", flink.peak_throughput)),
                (
                    "advantage",
                    ratio(holon.peak_throughput, flink.peak_throughput),
                ),
                ("holon_consumed", holon.consumed.to_string()),
                ("flink_consumed", flink.consumed.to_string()),
            ],
        );
    }
}
