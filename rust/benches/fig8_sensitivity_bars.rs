//! Figure 8 — latency sensitivity across failure scenarios: one bar per
//! (system, scenario) pair. Paper shape: Holon ≥ 20× lower sensitivity
//! than Flink in every scenario.

mod common;

use common::{failure_cfg, FAILURE_T0};
use holon::benchkit::{row, section};
use holon::experiments::{run_flink, run_holon, Scenario, Workload};

fn main() {
    let cfg = failure_cfg();
    section("Figure 8 — latency sensitivity across failure scenarios");

    let holon_base = run_holon(&cfg, Workload::Q7, vec![]);
    let flink_base = run_flink(&cfg, Workload::Q7, false, vec![]);

    for scenario in [
        Scenario::ConcurrentFailures,
        Scenario::SubsequentFailures,
        Scenario::CrashFailures,
    ] {
        let holon = run_holon(&cfg, Workload::Q7, scenario.schedule(FAILURE_T0));
        let flink = run_flink(&cfg, Workload::Q7, false, scenario.schedule(FAILURE_T0));
        let s_holon = holon.sensitivity_vs(&holon_base);
        let s_flink = flink.sensitivity_vs(&flink_base);
        row(
            scenario.name(),
            &[
                ("holon_s2", format!("{s_holon:.2}")),
                ("flink_s2", format!("{s_flink:.2}")),
                (
                    "flink/holon",
                    format!("{:.0}x", s_flink / s_holon.max(1e-9)),
                ),
            ],
        );
    }
}
