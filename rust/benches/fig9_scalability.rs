//! Figure 9 — average latency for Q7 as the cluster scales from 10 to
//! 100 nodes, with the input volume scaling with cluster size (the
//! paper's single-host methodology: all nodes in-process on one server).
//!
//! Paper shape: Holon achieves lower latency at every size (0.64 s vs
//! 2.45 s at 10 nodes, 3.8×) and degrades more gently: the baseline's
//! root/tree latency grows with stragglers across more sources, while
//! Holon's gossip path is per-node constant.

mod common;

use holon::benchkit::{ratio, row, section};
use holon::config::HolonConfig;
use holon::experiments::{run_flink, run_holon, Workload};

fn main() {
    section("Figure 9 — avg Q7 latency vs cluster size (input scales with size)");
    for &nodes in &[10u32, 20, 40, 70, 100] {
        let mut cfg = HolonConfig::default();
        cfg.nodes = nodes;
        cfg.partitions = nodes; // one partition per node, as in §5.3
        cfg.events_per_sec_per_partition = 1000; // scaled-down 10k/node
        // slow the sim down as the host gets oversubscribed, so the
        // measured latencies reflect the algorithms, not CPU starvation
        cfg.wall_ms_per_sim_sec = 20.0 + 3.0 * nodes as f64;
        cfg.duration_ms = 15_000;
        cfg.window_ms = 1000;
        // sampled gossip (Pekko-style): O(n·fanout) traffic per round,
        // paced down with cluster size to bound join CPU on one host
        cfg.gossip_fanout = 4;
        cfg.gossip_interval_ms = 100 + 2 * nodes as u64;
        // detection tolerance grows with cluster size (scheduler noise
        // on an oversubscribed single host must not read as failures)
        cfg.failure_timeout_ms = 600 + 10 * nodes as u64;

        let holon = run_holon(&cfg, Workload::Q7, vec![]);
        let flink = run_flink(&cfg, Workload::Q7, false, vec![]);
        row(
            &format!("{nodes} nodes"),
            &[
                ("holon_avg_s", format!("{:.2}", holon.latency_mean_ms / 1000.0)),
                ("flink_avg_s", format!("{:.2}", flink.latency_mean_ms / 1000.0)),
                (
                    "advantage",
                    ratio(flink.latency_mean_ms, holon.latency_mean_ms),
                ),
                ("holon_consumed", holon.consumed.to_string()),
                ("flink_consumed", flink.consumed.to_string()),
            ],
        );
    }
}
