//! Figure 6 — latency & throughput during node failure scenarios
//! (Holon top, Flink bottom). Regenerates the time series of §5.2: Q7
//! on five nodes; two nodes failed at t=20 s per scenario.
//!
//! Expected shape (paper): Holon recovers within ~2 s and catches up;
//! Flink takes tens of seconds (detection 6 s + restart 10 s + restore
//! + replay); on crash without restart Holon reconfigures and continues
//! while Flink stalls.

mod common;

use common::{failure_cfg, FAILURE_T0};
use holon::benchkit::{row, secs, section, sparkline};
use holon::experiments::{run_flink, run_holon, RunResult, Scenario, Workload};

fn print_series(label: &str, r: &RunResult) {
    let lat: Vec<f64> = r.latency_series.iter().map(|v| v.unwrap_or(0.0)).collect();
    println!("{label:<22} latency    {}", sparkline(&lat));
    println!("{label:<22} throughput {}", sparkline(&r.throughput_series));
    // numeric rows for EXPERIMENTS.md (one sample per 2 s of sim time)
    let step = 4; // 4 x 500ms buckets
    let lat_samples: Vec<String> = lat
        .iter()
        .step_by(step)
        .map(|v| format!("{:.0}", v))
        .collect();
    println!("{label:<22} lat_ms[2s] {}", lat_samples.join(","));
}

/// Disturbance duration after the failure: buckets with *no* output
/// (outage) plus buckets with latency > 3x the pre-failure mean
/// (catch-up), in paper-seconds.
fn recovery_seconds(r: &RunResult, pre_fail_buckets: usize) -> f64 {
    if r.latency_series.len() <= pre_fail_buckets {
        return 0.0;
    }
    let pre: Vec<f64> = r.latency_series[..pre_fail_buckets]
        .iter()
        .filter_map(|v| *v)
        .collect();
    let pre_mean = pre.iter().sum::<f64>() / pre.len().max(1) as f64;
    let disturbed = r.latency_series[pre_fail_buckets..]
        .iter()
        .filter(|v| match v {
            None => true,                          // outage: nothing emitted
            Some(x) => *x > 3.0 * pre_mean.max(1.0), // catch-up spike
        })
        .count();
    disturbed as f64 * 0.5
}

fn main() {
    let cfg = failure_cfg();
    for scenario in [
        Scenario::ConcurrentFailures,
        Scenario::SubsequentFailures,
        Scenario::CrashFailures,
    ] {
        section(&format!("Figure 6 — {}", scenario.name()));
        let holon = run_holon(&cfg, Workload::Q7, scenario.schedule(FAILURE_T0));
        let flink = run_flink(&cfg, Workload::Q7, false, scenario.schedule(FAILURE_T0));
        print_series("Holon", &holon);
        print_series("Flink (model)", &flink);

        let pre = (FAILURE_T0 / common::BUCKET_MS) as usize;
        row(
            "recovery (elevated lat.)",
            &[
                ("holon_s", format!("{:.1}", recovery_seconds(&holon, pre))),
                ("flink_s", format!("{:.1}", recovery_seconds(&flink, pre))),
            ],
        );
        row(
            "avg latency",
            &[
                ("holon_s", secs(holon.latency_mean_ms)),
                ("flink_s", secs(flink.latency_mean_ms)),
            ],
        );
        row(
            "outputs (progress)",
            &[
                ("holon", holon.outputs.to_string()),
                ("flink", flink.outputs.to_string()),
            ],
        );
        if scenario == Scenario::CrashFailures {
            println!(
                "crash: Holon continues after reconfiguration ({} steals); the \
                 baseline without spare slots stalls permanently",
                holon.steals
            );
        }
    }
}
