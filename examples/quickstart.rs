//! Quickstart: a global windowed aggregation in a few declarative lines
//! of the dataflow API v2.
//!
//! Builds a 3-node, 6-partition deployment, streams Nexmark events into
//! the logged input topic, and prints the *global* bid count per 1 s
//! window as seen by every partition — the counts always agree because
//! completed windows of a Windowed CRDT read the same on every replica
//! (deterministic reads, paper §3.3), with no coordination on the hot
//! path.
//!
//! Run: cargo run --release --example quickstart

use holon::api::Dataflow;
use holon::clock::SimClock;
use holon::codec::Decode;
use holon::config::HolonConfig;
use holon::crdt::GCounter;
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::nexmark::{producer, Event};

fn main() {
    // The whole query: count bids globally per tumbling second.
    let bids_per_window = Dataflow::<Event>::source()
        .filter(|ev| ev.is_bid())
        .tumbling(1000)
        .aggregate(|p, _ev, c: &mut GCounter| c.add(p as u64, 1))
        .emit_typed(|w, c| Some((w, c.value())));

    let mut cfg = HolonConfig::default();
    cfg.nodes = 3;
    cfg.partitions = 6;
    cfg.events_per_sec_per_partition = 1000;
    cfg.wall_ms_per_sim_sec = 50.0; // 1 paper-second runs in 50 ms
    cfg.duration_ms = 8000; // 8 paper-seconds of input
    cfg.window_ms = 1000; // 1 s tumbling windows

    println!("starting {} nodes / {} partitions ...", cfg.nodes, cfg.partitions);
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), bids_per_window, clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );

    std::thread::sleep(clock.wall_for(cfg.duration_ms + 4000));
    let produced = prod.stop();
    cluster.stop();

    println!("produced {produced} events; collecting per-window global counts ...\n");
    // decode deduplicated outputs per partition
    let mut per_part: Vec<Vec<(u64, u64)>> = Vec::new();
    for p in 0..cfg.partitions {
        let (recs, _) = cluster.output.read(p, 0, usize::MAX >> 1);
        let mut seen = 0u64;
        let mut outs = Vec::new();
        for rec in recs {
            let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
            if seq < seen {
                continue;
            }
            seen = seq + 1;
            outs.push(<(u64, u64)>::from_bytes(&inner).unwrap());
        }
        per_part.push(outs);
    }

    let windows = per_part.iter().map(|o| o.len()).min().unwrap_or(0);
    println!("window | global bid count (identical on all {} partitions)", cfg.partitions);
    for w in 0..windows {
        let (wid, count) = per_part[0][w];
        let agree = per_part.iter().all(|outs| outs[w] == (wid, count));
        println!("{:>6} | {:>7}  agree={}", wid, count, agree);
    }
    println!(
        "\nmean end-to-end latency: {:.0} sim-ms (p99 {} sim-ms) over {} outputs",
        cluster.metrics.latency.mean(),
        cluster.metrics.latency.p99(),
        cluster.metrics.outputs.load(std::sync::atomic::Ordering::Acquire),
    );
}
