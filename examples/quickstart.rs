//! Quickstart: the paper's Query 1 (Listing 2) on a small Holon cluster.
//!
//! Builds a 3-node, 6-partition deployment, streams Nexmark events into
//! the logged input topic, and prints each partition's ratio of local to
//! global bids per window — the ratios of one window always sum to 1
//! because the windowed GCounter gives every partition the same global
//! count (deterministic reads of completed windows).
//!
//! Run: cargo run --release --example quickstart

use holon::clock::SimClock;
use holon::codec::Decode;
use holon::config::HolonConfig;
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::nexmark::producer;
use holon::nexmark::queries::{Query1, RatioOut};

fn main() {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 3;
    cfg.partitions = 6;
    cfg.events_per_sec_per_partition = 1000;
    cfg.wall_ms_per_sim_sec = 50.0; // 1 paper-second runs in 50 ms
    cfg.duration_ms = 8000; // 8 paper-seconds of input
    cfg.window_ms = 1000; // 1 s tumbling windows

    println!("starting {} nodes / {} partitions ...", cfg.nodes, cfg.partitions);
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg.clone(), Query1::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );

    std::thread::sleep(clock.wall_for(cfg.duration_ms + 4000));
    let produced = prod.stop();
    cluster.stop();

    println!("produced {produced} events; collecting per-window ratios ...\n");
    // decode deduplicated outputs per partition
    let mut per_part: Vec<Vec<RatioOut>> = Vec::new();
    for p in 0..cfg.partitions {
        let (recs, _) = cluster.output.read(p, 0, usize::MAX >> 1);
        let mut seen = 0u64;
        let mut outs = Vec::new();
        for rec in recs {
            let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
            if seq < seen {
                continue;
            }
            seen = seq + 1;
            outs.push(RatioOut::from_bytes(&inner).unwrap());
        }
        per_part.push(outs);
    }

    let windows = per_part.iter().map(|o| o.len()).min().unwrap_or(0);
    println!("window |  global | per-partition ratios (sum = 1.0)");
    for w in 0..windows {
        let total = per_part[0][w].total;
        let ratios: Vec<String> = per_part
            .iter()
            .map(|outs| format!("{:.3}", outs[w].ratio()))
            .collect();
        let sum: f64 = per_part.iter().map(|outs| outs[w].ratio()).sum();
        println!(
            "{:>6} | {:>7} | {}  (sum {:.3})",
            w,
            total,
            ratios.join(" "),
            sum
        );
    }
    println!(
        "\nmean end-to-end latency: {:.0} sim-ms (p99 {} sim-ms) over {} outputs",
        cluster.metrics.latency.mean(),
        cluster.metrics.latency.p99(),
        cluster.metrics.outputs.load(std::sync::atomic::Ordering::Acquire),
    );
}
