//! Scalability sweep (the paper's Figure 9 shape, scaled down for an
//! example): average Q7 latency as the cluster grows, Holon vs the
//! centralized baseline. Input volume scales with cluster size, as in
//! the paper's single-host methodology (§5.3).
//!
//! Run: cargo run --release --example scalability

use holon::benchkit::{ratio, row, secs, section};
use holon::config::HolonConfig;
use holon::experiments::{run_flink, run_holon, Workload};

fn main() {
    section("Q7 average latency vs cluster size (volume scales with nodes)");
    for nodes in [4u32, 8, 16] {
        let mut cfg = HolonConfig::default();
        cfg.nodes = nodes;
        cfg.partitions = nodes * 2;
        cfg.events_per_sec_per_partition = 1000;
        cfg.wall_ms_per_sim_sec = 20.0;
        cfg.duration_ms = 15_000;
        cfg.window_ms = 1000;

        let holon = run_holon(&cfg, Workload::Q7, vec![]);
        let flink = run_flink(&cfg, Workload::Q7, false, vec![]);
        row(
            &format!("{nodes} nodes"),
            &[
                ("holon_avg_s", secs(holon.latency_mean_ms)),
                ("flink_avg_s", secs(flink.latency_mean_ms)),
                (
                    "advantage",
                    ratio(flink.latency_mean_ms, holon.latency_mean_ms),
                ),
                ("holon_consumed", holon.consumed.to_string()),
            ],
        );
    }
    println!("\nThe full 10..100-node sweep is `cargo bench --bench fig9_scalability`.");
}
