//! The dataflow API v2 (paper §3.1): two windowed queries — top-3 bids
//! and per-category bid counts — declared in a handful of lines and
//! fanned out of ONE event stream inside ONE engine job via
//! `MultiQuery`. Determinism, exactly-once and work stealing are
//! inherited from the engine; §3.2's out-of-order handling shows up as
//! `allowed_lateness`.
//!
//! Run: cargo run --release --example dataflow_api

use holon::api::{demux, Dataflow, MultiQuery};
use holon::clock::SimClock;
use holon::codec::{Decode, Reader, Writer};
use holon::config::HolonConfig;
use holon::crdt::{BoundedTopK, GCounter};
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::nexmark::{producer, Event};

fn main() {
    // Branch 0: top-3 bids per 1 s window, tolerating 100 ms disorder.
    let top3 = Dataflow::<Event>::source()
        .tumbling(1000)
        .allowed_lateness(100)
        .aggregate(|p, ev, tk: &mut BoundedTopK| {
            if let Event::Bid { auction, price, .. } = ev {
                tk.set_k(3); // keep the top three bids, not just the max
                tk.offer(*price, *auction, p as u64);
            }
        })
        .emit_raw(|w, tk| {
            let mut wr = Writer::new();
            wr.put_u64(w);
            let top: Vec<(f64, u64)> = tk.top().iter().map(|&(s, a, _)| (s.0, a)).collect();
            wr.put_u32(top.len() as u32);
            for (price, auction) in top {
                wr.put_f64(price);
                wr.put_u64(auction);
            }
            Some(wr.into_bytes())
        });

    // Branch 1: bid count per category per window (keyed aggregation —
    // no shuffle, just a windowed MapCrdt of GCounters).
    let per_category = Dataflow::<Event>::source()
        .filter(|ev| ev.is_bid())
        .tumbling(1000)
        .key_by(|ev| match ev {
            Event::Bid { category, .. } => *category,
            _ => 0,
        })
        .aggregate(|p, _ev, c: &mut GCounter| c.add(p as u64, 1))
        .emit_typed(|w, m| {
            let rows: Vec<(u64, u64)> = m.iter().map(|(&cat, c)| (cat, c.value())).collect();
            Some((w, rows))
        });

    // One engine job runs both pipelines over the same input stream.
    let fanout = MultiQuery::new(top3, per_category);

    let mut cfg = HolonConfig::default();
    cfg.nodes = 3;
    cfg.partitions = 6;
    cfg.events_per_sec_per_partition = 1000;
    cfg.wall_ms_per_sim_sec = 50.0;
    cfg.duration_ms = 6000;

    println!("top-3 bids + per-category counts, one MultiQuery job:\n");
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), fanout, clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 4000));
    prod.stop();
    cluster.stop();

    // read partition 0's deduplicated outputs (all partitions agree)
    let (recs, _) = cluster.output.read(0, 0, usize::MAX >> 1);
    let mut seen = 0u64;
    for rec in recs {
        let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
        if seq < seen {
            continue;
        }
        seen = seq + 1;
        match demux(&inner) {
            (0, bytes) => {
                let mut r = Reader::new(bytes);
                let w = r.get_u64().unwrap();
                let n = r.get_u32().unwrap();
                let mut tops = Vec::new();
                for _ in 0..n {
                    let price = r.get_f64().unwrap();
                    let auction = r.get_u64().unwrap();
                    tops.push(format!("${price:.2} (auction {auction})"));
                }
                println!("window {w} top bids: {}", tops.join("  >  "));
            }
            (_, bytes) => {
                let (w, rows) = <(u64, Vec<(u64, u64)>)>::from_bytes(bytes).unwrap();
                let cats: Vec<String> =
                    rows.iter().map(|(cat, n)| format!("c{cat}:{n}")).collect();
                println!("window {w} bids/category: {}", cats.join(" "));
            }
        }
    }
    println!(
        "\n{} outputs, mean latency {:.0} sim-ms — both queries share one job's \
         gossip, checkpoints and guarantees.",
        cluster.metrics.outputs.load(std::sync::atomic::Ordering::Acquire),
        cluster.metrics.latency.mean()
    );
}
