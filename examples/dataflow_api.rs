//! The dataflow API (paper §3.1): Q7 declared in a handful of lines —
//! the Flink-like veneer over the procedural API, with the determinism,
//! exactly-once and work-stealing guarantees inherited from the engine.
//! Also demonstrates §3.2's out-of-order handling (`allowed_lateness`).
//!
//! Run: cargo run --release --example dataflow_api

use holon::api::WindowQueryBuilder;
use holon::clock::SimClock;
use holon::codec::{Encode, Writer};
use holon::config::HolonConfig;
use holon::crdt::BoundedTopK;
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::nexmark::{producer, Event};

fn main() {
    // Q7 ("highest bid per window") in the dataflow API:
    let q7 = WindowQueryBuilder::<BoundedTopK>::tumbling(1000)
        .allowed_lateness(100) // tolerate 100 ms of event disorder
        .insert(|p, ev, tk| {
            if let Event::Bid { auction, price, .. } = ev {
                tk.set_k(3); // keep the top three bids, not just the max
                tk.offer(*price, *auction, p as u64);
            }
        })
        .emit(|w, tk| {
            let mut wr = Writer::new();
            wr.put_u64(w);
            let top: Vec<(f64, u64)> = tk.top().iter().map(|&(s, a, _)| (s.0, a)).collect();
            wr.put_u32(top.len() as u32);
            for (price, auction) in top {
                wr.put_f64(price);
                wr.put_u64(auction);
            }
            Some(wr.into_bytes())
        });

    let mut cfg = HolonConfig::default();
    cfg.nodes = 3;
    cfg.partitions = 6;
    cfg.events_per_sec_per_partition = 1000;
    cfg.wall_ms_per_sim_sec = 50.0;
    cfg.duration_ms = 6000;

    println!("top-3 bids per 1s window, declared in the dataflow API:\n");
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), q7, clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 4000));
    prod.stop();
    cluster.stop();

    // read partition 0's deduplicated outputs (all partitions agree)
    let (recs, _) = cluster.output.read(0, 0, usize::MAX >> 1);
    let mut seen = 0u64;
    for rec in recs {
        let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
        if seq < seen {
            continue;
        }
        seen = seq + 1;
        let mut r = holon::codec::Reader::new(&inner);
        let w = r.get_u64().unwrap();
        let n = r.get_u32().unwrap();
        let mut tops = Vec::new();
        for _ in 0..n {
            let price = r.get_f64().unwrap();
            let auction = r.get_u64().unwrap();
            tops.push(format!("${price:.2} (auction {auction})"));
        }
        println!("window {w}: {}", tops.join("  >  "));
    }
    let _ = Encode::to_bytes(&0u8); // keep the Encode import exercised
    println!(
        "\n{} outputs, mean latency {:.0} sim-ms — same guarantees as the procedural API.",
        cluster.metrics.outputs.load(std::sync::atomic::Ordering::Acquire),
        cluster.metrics.latency.mean()
    );
}
