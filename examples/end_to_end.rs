//! End-to-end driver (DESIGN.md validation run): the full three-layer
//! stack on a real small workload, proving all layers compose:
//!
//! * L1/L2 — the AOT Pallas/JAX window-aggregation kernel, lowered to
//!   HLO text by `make artifacts` and executed from Rust via PJRT on
//!   every Q7 batch (`use_xla = true`);
//! * L3 — the Holon coordinator: logged streams, gossip-synchronized
//!   Windowed CRDTs, work stealing;
//! * plus the baseline system on the same workload, reporting the
//!   paper's headline metric (end-to-end latency and throughput,
//!   Holon vs Flink-model, Nexmark Q7).
//!
//! Results of this run are recorded in EXPERIMENTS.md.
//!
//! Run: make artifacts && cargo run --release --example end_to_end

use holon::benchkit::{ratio, row, secs, section};
use holon::config::HolonConfig;
use holon::experiments::{run_flink, run_holon, Scenario, Workload};

fn main() {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 5;
    cfg.partitions = 10;
    cfg.events_per_sec_per_partition = 2000;
    // generous time scale: the AOT kernel dispatches via PJRT on every
    // batch of every partition — on this single-core host the sim must
    // leave wall-time headroom for it (1 paper-second = 200 ms here)
    cfg.wall_ms_per_sim_sec = 200.0;
    cfg.duration_ms = 20_000;
    cfg.window_ms = 1000;
    cfg.use_xla = true; // L1/L2 on the hot path

    if !std::path::Path::new(&cfg.artifacts_dir)
        .join("window_agg.hlo.txt")
        .exists()
    {
        eprintln!("warning: artifacts/ missing — run `make artifacts` first; falling back to the scalar aggregator");
    }

    section("End-to-end: Nexmark Q7, 5 nodes, 10 partitions, 20k events/s");
    println!("Holon runs with the AOT XLA window-aggregation kernel on the batch path.");

    let holon = run_holon(&cfg, Workload::Q7, vec![]);
    let flink = run_flink(&cfg, Workload::Q7, false, vec![]);

    row(
        "Holon",
        &[
            ("avg_latency_s", secs(holon.latency_mean_ms)),
            ("p99_s", secs(holon.latency_p99_ms as f64)),
            ("outputs", holon.outputs.to_string()),
            ("consumed", holon.consumed.to_string()),
        ],
    );
    row(
        "Flink (model)",
        &[
            ("avg_latency_s", secs(flink.latency_mean_ms)),
            ("p99_s", secs(flink.latency_p99_ms as f64)),
            ("outputs", flink.outputs.to_string()),
            ("consumed", flink.consumed.to_string()),
        ],
    );
    row(
        "latency advantage",
        &[(
            "holon_vs_flink",
            ratio(flink.latency_mean_ms, holon.latency_mean_ms),
        )],
    );

    section("Same workload under concurrent node failures (t=10s, restart t=20s)");
    let holon_f = run_holon(&cfg, Workload::Q7, Scenario::ConcurrentFailures.schedule(10_000));
    let flink_f = run_flink(
        &cfg,
        Workload::Q7,
        false,
        Scenario::ConcurrentFailures.schedule(10_000),
    );
    row(
        "Holon",
        &[
            ("avg_latency_s", secs(holon_f.latency_mean_ms)),
            ("p99_s", secs(holon_f.latency_p99_ms as f64)),
            ("steals", holon_f.steals.to_string()),
        ],
    );
    row(
        "Flink (model)",
        &[
            ("avg_latency_s", secs(flink_f.latency_mean_ms)),
            ("p99_s", secs(flink_f.latency_p99_ms as f64)),
        ],
    );
    row(
        "failure advantage",
        &[(
            "holon_vs_flink",
            ratio(flink_f.latency_mean_ms, holon_f.latency_mean_ms),
        )],
    );

    println!("\nAll layers composed: AOT artifacts loaded via PJRT, executed per batch");
    println!("inside the Rust node loop; no Python on the request path.");
}
