//! Failure-recovery demo (the paper's Figure 6 scenario, §5.2): run
//! Nexmark Q7 on five nodes, kill two of them mid-run, restart them ten
//! paper-seconds later, and watch latency and throughput — Holon keeps
//! making progress via work stealing and recovers within ~1–2
//! paper-seconds, while the same scenario stalls the centralized
//! baseline for tens of seconds (run the fig6 bench for the side-by-side).
//!
//! Run: cargo run --release --example failure_recovery

use holon::benchkit::sparkline;
use holon::config::HolonConfig;
use holon::experiments::{run_holon, Scenario, Workload};

fn main() {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 5;
    cfg.partitions = 10;
    cfg.events_per_sec_per_partition = 1000;
    cfg.wall_ms_per_sim_sec = 20.0;
    cfg.duration_ms = 40_000;
    cfg.window_ms = 1000;

    println!("Q7 on 5 nodes; concurrent failure of nodes 1 and 2 at t=15s, restart at t=25s");
    let result = run_holon(&cfg, Workload::Q7, Scenario::ConcurrentFailures.schedule(15_000));

    let lat: Vec<f64> = result
        .latency_series
        .iter()
        .map(|v| v.unwrap_or(0.0))
        .collect();
    println!("\nlatency over time   (500 ms buckets, ▁=low █=high):");
    println!("  {}", sparkline(&lat));
    println!("throughput over time:");
    println!("  {}", sparkline(&result.throughput_series));

    let peak = lat.iter().copied().fold(0.0, f64::max);
    println!("\nmean latency {:.0} sim-ms | p99 {} sim-ms | peak bucket {:.0} sim-ms",
        result.latency_mean_ms, result.latency_p99_ms, peak);
    println!(
        "outputs {} | consumed {} of {} produced | work steals {}",
        result.outputs, result.consumed, result.produced, result.steals
    );

    // recovery time: buckets (after the failure) whose latency exceeds
    // 3x the pre-failure mean
    let fail_bucket = 15_000 / 500;
    let pre: Vec<f64> = lat[..fail_bucket].to_vec();
    let pre_mean = pre.iter().sum::<f64>() / pre.len().max(1) as f64;
    let disturbed = lat[fail_bucket..]
        .iter()
        .filter(|&&v| v > 3.0 * pre_mean)
        .count();
    println!(
        "buckets disturbed after failure: {} (≈ {:.1} paper-seconds of elevated latency)",
        disturbed,
        disturbed as f64 * 0.5
    );
}
